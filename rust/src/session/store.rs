//! `DiskStore` — the persistent tier of the artifact cache: a versioned,
//! checksummed binary serialization of [`Preprocessed`] (the full Alg.-1
//! output *including the compiled [`ExecutionPlan`]*), content-addressed
//! by [`ArtifactKey`].
//!
//! The paper's premise is that preprocessing is an **offline, reusable**
//! step (GraphR treats it so explicitly; AutoGMap persists the crossbar
//! mapping as a compiled artifact): static pattern assignment only
//! amortizes crossbar writes if the assignment itself survives process
//! restarts. This module is the software analogue — a restarted serve
//! fleet warm-starts from disk and performs **zero plan compilations**
//! for every key already baked (asserted via
//! [`ArtifactStats`](super::ArtifactStats) in the integration suite).
//!
//! # File format (`plan-v<FORMAT>.<SCHEMA>-<keyhash>.rpa`)
//!
//! Hand-rolled explicit little-endian framing ([`util::codec`]) — no
//! serde, no `#[repr]` tricks, byte-stable across platforms and builds:
//!
//! ```text
//! magic    8 B   b"RPREPROC"
//! format   u32   envelope version (FORMAT_VERSION) — framing layout
//! schema   u32   payload version (SCHEMA_VERSION) — bump whenever any
//!                persisted in-memory type changes shape
//! key      var   the full ArtifactKey (dataset short name, fixed-point
//!                scale, weighted flag, arch signature, shard stamp —
//!                schema ≥ 4) — compared, not trusted, on load
//! deltas   24 B  DeltaProvenance (schema ≥ 2): batches / dirty
//!                partitions / patched ops absorbed since the last cold
//!                compile — all zero for a cold save
//! timing   36 B  PreprocessTiming (schema ≥ 3): phase-split wall clock
//!                of the cold compile that produced this artifact and
//!                the thread count it fanned out over (informational —
//!                carried across patch republishes unchanged)
//! payload  var   Partitioned ▸ PatternRanking ▸ ConfigTable ▸
//!                SubgraphTable ▸ ExecutionPlan (every section framed by
//!                its own module; derived state — hash indices, the
//!                plan's lane and gather tables — is rebuilt on decode,
//!                never persisted or trusted from the file)
//! checksum u64   FNV-1a over every preceding byte
//! ```
//!
//! # Invalidation rules
//!
//! * **Envelope**: wrong magic / format version → typed error, caller
//!   recomputes. The format version is also baked into the *filename*,
//!   so a bumped binary simply misses old files (they become orphans
//!   that [`DiskStore::clear`] still removes).
//! * **Integrity**: any flipped byte or truncation → [`StoreError::Checksum`]
//!   / [`StoreError::Truncated`]; the corrupt file is deleted by the
//!   [`ArtifactStore`](super::ArtifactStore) fallback path and rewritten
//!   after recompute. A corrupt plan is **never served** — decode
//!   additionally re-validates every cross-section index the interpreter
//!   would chase.
//! * **Identity**: the embedded key must equal the requested key
//!   byte-for-byte (covers `ArchConfig` mismatches even under filename
//!   collisions or copied files), and the decoded plan must satisfy
//!   [`ExecutionPlan::matches`] for the architecture in hand.
//!
//! # Concurrency
//!
//! Writers publish via write-to-temp + [`std::fs::hard_link`] to the
//! final name: link creation is atomic and fails if the target exists,
//! so N racing stores (threads *or* processes) produce exactly one
//! on-disk write and readers only ever observe complete files.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::accel::{ArchConfig, Preprocessed, PreprocessTiming};
use crate::pattern::extract::{Partitioned, Subgraph};
use crate::pattern::rank::PatternRanking;
use crate::pattern::tables::{
    ConfigTable, CtEntry, EngineSlot, ExecOrder, StEntry, StaticAssignment, SubgraphTable,
};
use crate::pattern::Pattern;
use crate::sched::ExecutionPlan;
use crate::util::codec::{fnv1a64, CodecError, Reader, Writer};

use super::ArtifactKey;

/// Envelope framing version (magic/version/key/checksum layout).
pub const FORMAT_VERSION: u32 = 1;
/// Payload schema version: bump whenever `Partitioned`, the ranking, the
/// CT/ST, or the `ExecutionPlan` sections change shape.
/// v2: a [`DeltaProvenance`] section follows the key — how much streaming
/// mutation the artifact has absorbed since its last cold compile.
/// v3: a [`PreprocessTiming`] section follows the provenance — the
/// phase-split wall clock of the artifact's cold compile (and the thread
/// count it fanned out over), so `repro artifacts ls` can show what each
/// cached plan cost to build, cross-process.
/// v4: the embedded [`ArtifactKey`] grew a shard stamp (`shard_id` of
/// `shard_count`) — per-shard artifacts of a block-row split persist
/// under distinct keys; a 1-shard key encodes as `0/1` so unsharded
/// sessions keep their key identity (but v3 files lack the two fields
/// entirely, hence the bump).
pub const SCHEMA_VERSION: u32 = 4;

const MAGIC: [u8; 8] = *b"RPREPROC";
const FILE_PREFIX: &str = "plan-v";
const FILE_EXT: &str = "rpa";
/// magic + format version — everything before the checksummed reader.
const ENVELOPE_HEAD: usize = 8 + 4;
/// Smallest structurally possible file: head + schema + checksum.
const MIN_LEN: usize = ENVELOPE_HEAD + 4 + 8;

/// Typed load/save failure. Every variant is a *fall back to recompute*
/// signal for the cache — none of them is ever a panic.
#[derive(Debug)]
pub enum StoreError {
    /// Underlying I/O failure other than file-not-found.
    Io(std::io::Error),
    /// No artifact on disk for the key (an ordinary cold miss).
    Missing,
    /// File shorter than its framing promises.
    Truncated,
    /// Not an artifact file at all.
    BadMagic,
    /// Written by a different envelope format.
    FormatVersion { found: u32 },
    /// Written by a different payload schema.
    SchemaVersion { found: u32 },
    /// FNV-1a integrity check failed (bit rot, partial write, tamper).
    Checksum,
    /// The embedded key differs from the requested one (e.g. an
    /// `ArchConfig` mismatch behind a colliding or copied filename).
    KeyMismatch,
    /// The decoded plan does not match the architecture in hand.
    ArchMismatch,
    /// Framing was intact but a structural invariant of the payload was
    /// violated.
    Corrupt(&'static str),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "artifact I/O error: {e}"),
            StoreError::Missing => write!(f, "no on-disk artifact for this key"),
            StoreError::Truncated => write!(f, "artifact file truncated"),
            StoreError::BadMagic => write!(f, "not an artifact file (bad magic)"),
            StoreError::FormatVersion { found } => {
                write!(f, "artifact format v{found} (this binary reads v{FORMAT_VERSION})")
            }
            StoreError::SchemaVersion { found } => {
                write!(f, "artifact schema v{found} (this binary reads v{SCHEMA_VERSION})")
            }
            StoreError::Checksum => write!(f, "artifact checksum mismatch"),
            StoreError::KeyMismatch => {
                write!(f, "artifact was built for a different key (dataset/scale/arch)")
            }
            StoreError::ArchMismatch => {
                write!(f, "artifact plan does not match the requested architecture")
            }
            StoreError::Corrupt(what) => write!(f, "artifact payload corrupt: {what}"),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<CodecError> for StoreError {
    fn from(e: CodecError) -> Self {
        match e {
            CodecError::Truncated => StoreError::Truncated,
            CodecError::Invalid(what) => StoreError::Corrupt(what),
        }
    }
}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::NotFound {
            StoreError::Missing
        } else {
            StoreError::Io(e)
        }
    }
}

/// Streaming-mutation provenance of a persisted artifact: how much
/// delta patching ([`sched::patch`](crate::sched::patch)) it has
/// absorbed since its last cold compile. Purely informational — a
/// patched artifact is bit-identical to a cold recompile of the mutated
/// graph, so nothing downstream branches on these counters; they exist
/// so `repro artifacts ls` can tell a live-mutated cache entry from a
/// freshly baked one. All zero on a cold save.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeltaProvenance {
    /// Delta batches applied to this artifact.
    pub batches: u64,
    /// Total dirty adjacency windows across those batches.
    pub dirty_partitions: u64,
    /// Total plan ops re-emitted across those batches.
    pub patched_ops: u64,
}

impl DeltaProvenance {
    fn encode_into(&self, w: &mut Writer) {
        w.put_u64(self.batches);
        w.put_u64(self.dirty_partitions);
        w.put_u64(self.patched_ops);
    }

    fn decode_from(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(Self { batches: r.u64()?, dirty_partitions: r.u64()?, patched_ops: r.u64()? })
    }
}

/// Schema-v3 timing section: phase-split compile cost, stamped at cold
/// compile and carried verbatim across delta republishes. Local codec —
/// `PreprocessTiming` itself lives in `accel` and stays format-agnostic.
fn encode_timing(w: &mut Writer, t: &PreprocessTiming) {
    w.put_u64(t.partition_ns);
    w.put_u64(t.rank_ns);
    w.put_u64(t.tables_ns);
    w.put_u64(t.plan_ns);
    w.put_u32(t.threads);
}

fn decode_timing(r: &mut Reader<'_>) -> Result<PreprocessTiming, CodecError> {
    Ok(PreprocessTiming {
        partition_ns: r.u64()?,
        rank_ns: r.u64()?,
        tables_ns: r.u64()?,
        plan_ns: r.u64()?,
        threads: r.u32()?,
    })
}

/// The on-disk artifact directory. Cheap value type — all state lives in
/// the filesystem, so any number of `DiskStore`s (across threads and
/// processes) may point at one directory.
#[derive(Debug, Clone)]
pub struct DiskStore {
    dir: PathBuf,
}

impl DiskStore {
    /// Open (creating if needed) an artifact directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self, StoreError> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).map_err(StoreError::Io)?;
        Ok(Self { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Content address of a key: format + schema version and the key
    /// fingerprint are all in the name, so incompatible binaries never
    /// even open each other's files.
    pub fn path_of(&self, key: &ArtifactKey) -> PathBuf {
        self.dir.join(format!(
            "{FILE_PREFIX}{FORMAT_VERSION}.{SCHEMA_VERSION}-{:016x}.{FILE_EXT}",
            key.fingerprint()
        ))
    }

    /// Load and fully validate the artifact for `key`. `arch` is the
    /// architecture the caller will run under — the decoded plan must
    /// [`matches`](ExecutionPlan::matches) it.
    pub fn load(&self, key: &ArtifactKey, arch: &ArchConfig) -> Result<Preprocessed, StoreError> {
        self.load_with(key, arch).map(|(pre, _, _)| pre)
    }

    /// Like [`load`](Self::load) but also returns the artifact's
    /// accumulated [`DeltaProvenance`] and the [`PreprocessTiming`] of
    /// its cold compile (the delta-patch path carries both across a disk
    /// round trip).
    pub fn load_with(
        &self,
        key: &ArtifactKey,
        arch: &ArchConfig,
    ) -> Result<(Preprocessed, DeltaProvenance, PreprocessTiming), StoreError> {
        let bytes = std::fs::read(self.path_of(key))?;
        let (pre, prov, timing) = decode_artifact_with(&bytes, key)?;
        if !pre.plan.matches(arch) {
            return Err(StoreError::ArchMismatch);
        }
        Ok((pre, prov, timing))
    }

    /// Persist the artifact for `key`. Returns `Ok(false)` when another
    /// writer already published this key (the exactly-once path under a
    /// multi-store stampede); the existing file is left untouched.
    ///
    /// Exactly-once is guaranteed by the hard-link publish. On the rare
    /// filesystem without hard links (exFAT, some network mounts) the
    /// rename fallback keeps publishes *atomic* — readers never observe
    /// a partial file — but two racing writers may each report
    /// `Ok(true)` for identical bytes; `ArtifactStats::writes` can
    /// over-count by the race width there, never under-count.
    pub fn save(&self, key: &ArtifactKey, pre: &Preprocessed) -> Result<bool, StoreError> {
        self.save_with(key, pre, &DeltaProvenance::default(), &PreprocessTiming::default())
    }

    /// Like [`save`](Self::save) but stamping the artifact with its
    /// accumulated [`DeltaProvenance`] and compile [`PreprocessTiming`] —
    /// the cold-compile persist and the delta-patch republish path (which
    /// [`remove`](Self::remove)s the stale file first, so the
    /// exactly-once publish applies to each *generation* of the key).
    pub fn save_with(
        &self,
        key: &ArtifactKey,
        pre: &Preprocessed,
        prov: &DeltaProvenance,
        timing: &PreprocessTiming,
    ) -> Result<bool, StoreError> {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let target = self.path_of(key);
        if target.exists() {
            return Ok(false);
        }
        let bytes = encode_artifact_with(key, pre, prov, timing);
        let tmp = self.dir.join(format!(
            ".tmp-{:016x}-{}-{}",
            key.fingerprint(),
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        if let Err(e) = std::fs::write(&tmp, &bytes) {
            let _ = std::fs::remove_file(&tmp); // partial write: don't litter
            return Err(StoreError::Io(e));
        }
        // Atomic publish: link-to-final fails iff somebody else already
        // published, which is exactly the once-only semantics we want.
        match std::fs::hard_link(&tmp, &target) {
            Ok(()) => {
                let _ = std::fs::remove_file(&tmp);
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                let _ = std::fs::remove_file(&tmp);
                Ok(false)
            }
            // Filesystems without hard links: atomic rename (replaces on
            // a race, but both writers hold identical bytes).
            Err(_) => match std::fs::rename(&tmp, &target) {
                Ok(()) => Ok(true),
                Err(e) => {
                    let _ = std::fs::remove_file(&tmp);
                    Err(StoreError::Io(e))
                }
            },
        }
    }

    /// Remove the on-disk entry for `key` (if any). `true` if a file was
    /// deleted.
    pub fn remove(&self, key: &ArtifactKey) -> bool {
        std::fs::remove_file(self.path_of(key)).is_ok()
    }

    /// Remove every artifact file in the directory — including orphans
    /// written under older format/schema versions and stale `.tmp-*`
    /// leftovers from interrupted publishes — and return how many
    /// *artifacts* were deleted. Foreign files are left alone.
    pub fn clear(&self) -> usize {
        let mut removed = 0;
        for path in self.entries() {
            if std::fs::remove_file(&path).is_ok() {
                removed += 1;
            }
        }
        // A process killed between temp-write and publish leaves its
        // temp file behind (the publish path can't clean up what it
        // never reached); this is the one sweeper for those.
        if let Ok(dir) = std::fs::read_dir(&self.dir) {
            for path in dir.filter_map(|e| e.ok()).map(|e| e.path()) {
                if path
                    .file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(".tmp-"))
                {
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
        removed
    }

    /// Every artifact file currently in the directory (any version),
    /// sorted for deterministic listings.
    pub fn entries(&self) -> Vec<PathBuf> {
        let Ok(dir) = std::fs::read_dir(&self.dir) else {
            return Vec::new();
        };
        let mut out: Vec<PathBuf> = dir
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(FILE_PREFIX) && n.ends_with(FILE_EXT))
            })
            .collect();
        out.sort();
        out
    }

    /// The [`ArtifactKey`] embedded in an artifact file's header, when
    /// the file is readable under the current format and schema (stale
    /// files carry no key this binary can decode). Never decodes the
    /// payload. The streaming-mutation path uses this to sweep the
    /// shard-stamped variants of a patched key.
    pub fn embedded_key(path: &Path) -> Result<ArtifactKey, StoreError> {
        let bytes = std::fs::read(path)?;
        let format = envelope_format(&bytes)?;
        if format != FORMAT_VERSION {
            return Err(StoreError::FormatVersion { found: format });
        }
        let mut r = checked_payload(&bytes)?;
        let schema = r.u32()?;
        if schema != SCHEMA_VERSION {
            return Err(StoreError::SchemaVersion { found: schema });
        }
        Ok(ArtifactKey::decode_from(&mut r)?)
    }

    /// Human-readable one-line description of an artifact file (the
    /// `repro artifacts ls` view): versions, embedded key, size. Never
    /// decodes the payload.
    pub fn describe(path: &Path) -> Result<String, StoreError> {
        let bytes = std::fs::read(path)?;
        let format = envelope_format(&bytes)?;
        if format != FORMAT_VERSION {
            return Ok(format!("format v{format} (stale; this binary reads v{FORMAT_VERSION})"));
        }
        let mut r = checked_payload(&bytes)?;
        let schema = r.u32()?;
        // The v4 key codec grew a shard stamp, so older keys no longer
        // parse with it — stale schemas are reported, never decoded.
        if schema != SCHEMA_VERSION {
            return Ok(format!(
                "schema v{schema} (stale; this binary reads v{SCHEMA_VERSION})"
            ));
        }
        let key = ArtifactKey::decode_from(&mut r)?;
        let prov = DeltaProvenance::decode_from(&mut r)?;
        let deltas = if prov.batches > 0 {
            format!(
                "  deltas {} ({} dirty, {} ops)",
                prov.batches, prov.dirty_partitions, prov.patched_ops
            )
        } else {
            String::new()
        };
        let t = decode_timing(&mut r)?;
        let compiled = if t.total_ns() > 0 {
            format!("  compiled {}us on {} thread(s)", t.total_ns() / 1_000, t.threads.max(1))
        } else {
            String::new()
        };
        // "checksum ok", not "payload ok": this listing never decodes
        // the payload, so it must not vouch for more than it verified.
        Ok(format!(
            "v{format}.{schema}  {}  {} B{deltas}{compiled}  checksum ok",
            key.summary(),
            bytes.len()
        ))
    }
}

/// Envelope step 1 — length, magic, and the format-version field. The
/// format is returned (not judged): `decode_artifact` requires the
/// current one, `describe` reports stale ones as information.
fn envelope_format(bytes: &[u8]) -> Result<u32, StoreError> {
    if bytes.len() < MIN_LEN {
        return Err(StoreError::Truncated);
    }
    if bytes[..8] != MAGIC {
        return Err(StoreError::BadMagic);
    }
    Ok(u32::from_le_bytes(bytes[8..12].try_into().unwrap()))
}

/// Envelope step 2 — verify the trailing FNV-1a checksum and hand back a
/// reader positioned at the schema-version field. Only meaningful for
/// the current format version (older formats may frame differently).
fn checked_payload(bytes: &[u8]) -> Result<Reader<'_>, StoreError> {
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    if fnv1a64(body) != u64::from_le_bytes(tail.try_into().unwrap()) {
        return Err(StoreError::Checksum);
    }
    Ok(Reader::new(&body[ENVELOPE_HEAD..]))
}

/// Serialize `pre` under `key` into the full framed + checksummed file
/// image, with zeroed (cold-compile) provenance and timing.
pub fn encode_artifact(key: &ArtifactKey, pre: &Preprocessed) -> Vec<u8> {
    encode_artifact_with(key, pre, &DeltaProvenance::default(), &PreprocessTiming::default())
}

/// Serialize `pre` under `key`, stamped with its delta provenance and
/// the compile timing that produced it.
pub fn encode_artifact_with(
    key: &ArtifactKey,
    pre: &Preprocessed,
    prov: &DeltaProvenance,
    timing: &PreprocessTiming,
) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_bytes(&MAGIC);
    w.put_u32(FORMAT_VERSION);
    w.put_u32(SCHEMA_VERSION);
    key.encode_into(&mut w);
    prov.encode_into(&mut w);
    encode_timing(&mut w, timing);
    encode_partitioned(&mut w, &pre.part);
    encode_ranking(&mut w, &pre.ranking);
    encode_config_table(&mut w, &pre.ct);
    encode_subgraph_table(&mut w, &pre.st);
    pre.plan.encode_into(&mut w);
    let sum = fnv1a64(w.as_bytes());
    w.put_u64(sum);
    w.into_bytes()
}

/// Decode and validate a file image, discarding the provenance and
/// timing stamps.
pub fn decode_artifact(bytes: &[u8], expected: &ArtifactKey) -> Result<Preprocessed, StoreError> {
    decode_artifact_with(bytes, expected).map(|(pre, _, _)| pre)
}

/// Decode and validate a file image: envelope (magic, versions,
/// checksum), identity (embedded key == `expected`), then every payload
/// section with its structural invariants, then cross-section
/// consistency. Any failure is a typed [`StoreError`]. Returns the
/// artifact together with the [`DeltaProvenance`] and compile
/// [`PreprocessTiming`] it was saved under.
pub fn decode_artifact_with(
    bytes: &[u8],
    expected: &ArtifactKey,
) -> Result<(Preprocessed, DeltaProvenance, PreprocessTiming), StoreError> {
    let format = envelope_format(bytes)?;
    if format != FORMAT_VERSION {
        return Err(StoreError::FormatVersion { found: format });
    }
    let mut r = checked_payload(bytes)?;
    let schema = r.u32()?;
    if schema != SCHEMA_VERSION {
        return Err(StoreError::SchemaVersion { found: schema });
    }
    let key = ArtifactKey::decode_from(&mut r)?;
    if key != *expected {
        return Err(StoreError::KeyMismatch);
    }
    let prov = DeltaProvenance::decode_from(&mut r)?;
    let timing = decode_timing(&mut r)?;
    let part = decode_partitioned(&mut r)?;
    let ranking = decode_ranking(&mut r)?;
    let ct = decode_config_table(&mut r)?;
    let st = decode_subgraph_table(&mut r)?;
    let plan = ExecutionPlan::decode_from(&mut r)?;
    r.done()?;

    // Cross-section consistency: the sections must describe one another,
    // or the scheduler would index across mismatched tables.
    if plan.num_ops() != st.len() {
        return Err(StoreError::Corrupt("plan ops != subgraph-table entries"));
    }
    if ct.len() != ranking.num_patterns() || ct.len() as u32 != plan.num_patterns {
        return Err(StoreError::Corrupt("pattern table sizes diverge"));
    }
    if part.c != plan.c || part.num_vertices != plan.num_vertices {
        return Err(StoreError::Corrupt("partitioning geometry diverges from plan"));
    }
    let nsub = part.subgraphs.len() as u32;
    if st.entries.iter().any(|e| e.sg_idx >= nsub) {
        return Err(StoreError::Corrupt("subgraph-table index out of partitioning"));
    }
    // Ranking/CT patterns reach `Crossbar::configure` through the DSE
    // rebuild path (`build_config_table` → `rebuild_static_slots`), so
    // they obey the same C×C window rule as the plan's own tables.
    let cells = part.c * part.c;
    if cells < 64
        && (ranking.ranked.iter().any(|(p, _)| p.0 >> cells != 0)
            || ct.entries.iter().any(|e| e.pattern.0 >> cells != 0))
    {
        return Err(StoreError::Corrupt("table pattern outside the C×C window"));
    }
    Ok((Preprocessed { part, ranking, ct, st, plan }, prov, timing))
}

fn encode_partitioned(w: &mut Writer, part: &Partitioned) {
    w.put_u32(part.c as u32);
    w.put_u32(part.num_vertices);
    w.put_u64(part.subgraphs.len() as u64);
    for sg in &part.subgraphs {
        w.put_u32(sg.brow);
        w.put_u32(sg.bcol);
        w.put_u64(sg.pattern.0);
    }
    match &part.weights {
        None => w.put_u8(0),
        Some(per_sub) => {
            // Flattened in place (same bytes `put_f32s` of the
            // concatenation would produce, without materializing a
            // second copy of every edge weight); per-subgraph lengths
            // are implied by each pattern's nnz, which the decoder
            // re-splits on (and checks).
            w.put_u8(1);
            let total: usize = per_sub.iter().map(Vec::len).sum();
            w.put_u64(total as u64);
            for weights in per_sub {
                for &x in weights {
                    w.put_f32(x);
                }
            }
        }
    }
}

fn decode_partitioned(r: &mut Reader<'_>) -> Result<Partitioned, StoreError> {
    let c = r.u32()? as usize;
    if !(1..=crate::pattern::pattern::MAX_C).contains(&c) {
        return Err(StoreError::Corrupt("partition window size out of range"));
    }
    let num_vertices = r.u32()?;
    let n = r.prefixed_count(16)?;
    let cells = c * c;
    let mut subgraphs = Vec::with_capacity(n);
    for _ in 0..n {
        let sg = Subgraph { brow: r.u32()?, bcol: r.u32()?, pattern: Pattern(r.u64()?) };
        // Dense-weight expansion indexes `out[bit]` over a C×C buffer.
        if cells < 64 && sg.pattern.0 >> cells != 0 {
            return Err(StoreError::Corrupt("subgraph pattern outside the C×C window"));
        }
        subgraphs.push(sg);
    }
    let weights = match r.u8()? {
        0 => None,
        1 => {
            let flat = r.f32s()?;
            let mut per_sub = Vec::with_capacity(subgraphs.len());
            let mut at = 0usize;
            for sg in &subgraphs {
                let nnz = sg.pattern.nnz() as usize;
                let end = at
                    .checked_add(nnz)
                    .filter(|&e| e <= flat.len())
                    .ok_or(StoreError::Corrupt("weight data shorter than pattern nnz"))?;
                per_sub.push(flat[at..end].to_vec());
                at = end;
            }
            if at != flat.len() {
                return Err(StoreError::Corrupt("weight data longer than pattern nnz"));
            }
            Some(per_sub)
        }
        _ => return Err(StoreError::Corrupt("bad weights flag")),
    };
    Ok(Partitioned { c, num_vertices, subgraphs, weights })
}

fn encode_ranking(w: &mut Writer, ranking: &PatternRanking) {
    w.put_u64(ranking.ranked.len() as u64);
    for &(pattern, count) in &ranking.ranked {
        w.put_u64(pattern.0);
        w.put_u32(count);
    }
    w.put_u64(ranking.total_subgraphs as u64);
}

fn decode_ranking(r: &mut Reader<'_>) -> Result<PatternRanking, StoreError> {
    let n = r.prefixed_count(12)?;
    let mut ranked = Vec::with_capacity(n);
    for _ in 0..n {
        ranked.push((Pattern(r.u64()?), r.u32()?));
    }
    let total_subgraphs = r.u64()? as usize;
    // The rank index is derived state: rebuilt, never persisted.
    let rank_of = ranked
        .iter()
        .enumerate()
        .map(|(i, &(p, _))| (p, i as u32))
        .collect();
    Ok(PatternRanking { ranked, rank_of, total_subgraphs })
}

fn encode_config_table(w: &mut Writer, ct: &ConfigTable) {
    w.put_u64(ct.entries.len() as u64);
    for e in &ct.entries {
        w.put_u64(e.pattern.0);
        w.put_u32(e.occurrences);
        w.put_u32(e.slots.len() as u32);
        for s in &e.slots {
            w.put_u32(s.engine);
            w.put_u32(s.crossbar);
        }
        match e.row_addr {
            None => w.put_u8(0xFF),
            Some(row) => w.put_u8(row),
        }
        w.put_u32(e.active_rows);
    }
    w.put_u32(ct.num_static_engines);
    w.put_u32(ct.crossbars_per_engine);
    w.put_u8(ct.assignment.to_code());
}

fn decode_config_table(r: &mut Reader<'_>) -> Result<ConfigTable, StoreError> {
    // Min entry size: pattern u64 + occurrences u32 + slot count u32 +
    // row_addr u8 + active_rows u32 (slots themselves may be empty).
    let n = r.prefixed_count(21)?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let pattern = Pattern(r.u64()?);
        let occurrences = r.u32()?;
        // The per-entry slot count is a u32 prefix (not codec's u64
        // form), so it carries its own pre-allocation guard.
        let n_slots = r.u32()? as usize;
        let total = n_slots.checked_mul(8).ok_or(StoreError::Truncated)?;
        if total > r.remaining() {
            return Err(StoreError::Truncated);
        }
        let mut slots = Vec::with_capacity(n_slots);
        for _ in 0..n_slots {
            slots.push(EngineSlot { engine: r.u32()?, crossbar: r.u32()? });
        }
        let row_addr = match r.u8()? {
            0xFF => None,
            row => Some(row),
        };
        let active_rows = r.u32()?;
        entries.push(CtEntry { pattern, occurrences, slots, row_addr, active_rows });
    }
    let num_static_engines = r.u32()?;
    let crossbars_per_engine = r.u32()?;
    let assignment = StaticAssignment::from_code(r.u8()?)
        .ok_or(StoreError::Corrupt("unknown static-assignment code"))?;
    Ok(ConfigTable::from_parts(entries, num_static_engines, crossbars_per_engine, assignment))
}

fn encode_subgraph_table(w: &mut Writer, st: &SubgraphTable) {
    w.put_u8(st.order.to_code());
    w.put_u64(st.entries.len() as u64);
    for e in &st.entries {
        w.put_u32(e.sg_idx);
        w.put_u32(e.src_start);
        w.put_u32(e.dst_start);
        w.put_u32(e.pattern_rank);
    }
    w.put_u32s(&st.groups);
}

fn decode_subgraph_table(r: &mut Reader<'_>) -> Result<SubgraphTable, StoreError> {
    let order =
        ExecOrder::from_code(r.u8()?).ok_or(StoreError::Corrupt("unknown execution-order code"))?;
    let n = r.prefixed_count(16)?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        entries.push(StEntry {
            sg_idx: r.u32()?,
            src_start: r.u32()?,
            dst_start: r.u32()?,
            pattern_rank: r.u32()?,
        });
    }
    let groups = r.u32s()?;
    if groups.first() != Some(&0)
        || groups.last().copied() != Some(entries.len() as u32)
        || groups.windows(2).any(|w| w[0] > w[1])
    {
        return Err(StoreError::Corrupt("subgraph-table groups not a monotone cover"));
    }
    Ok(SubgraphTable { order, entries, groups })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Accelerator;
    use crate::graph::datasets::Dataset;

    fn scratch(tag: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "repro-store-{tag}-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn baked(weighted: bool) -> (ArtifactKey, Preprocessed, ArchConfig) {
        let acc = Accelerator::with_defaults();
        let key = ArtifactKey::new(Dataset::Tiny, 1.0, weighted, &acc.config);
        let g = if weighted {
            Dataset::Tiny.load_weighted(1.0).unwrap()
        } else {
            Dataset::Tiny.load().unwrap()
        };
        let pre = acc.preprocess(&g, weighted).unwrap();
        (key, pre, acc.config)
    }

    #[test]
    fn bytes_roundtrip_whole_artifact() {
        for weighted in [false, true] {
            let (key, pre, _) = baked(weighted);
            let bytes = encode_artifact(&key, &pre);
            let decoded = decode_artifact(&bytes, &key).unwrap();
            assert_eq!(pre, decoded, "weighted={weighted}");
        }
    }

    #[test]
    fn encoding_is_deterministic() {
        let (key, pre, _) = baked(false);
        assert_eq!(encode_artifact(&key, &pre), encode_artifact(&key, &pre));
    }

    #[test]
    fn save_load_and_exactly_once_publish() {
        let dir = scratch("once");
        let store = DiskStore::open(&dir).unwrap();
        let (key, pre, arch) = baked(false);
        assert!(matches!(store.load(&key, &arch), Err(StoreError::Missing)));
        assert!(store.save(&key, &pre).unwrap(), "first save writes");
        assert!(!store.save(&key, &pre).unwrap(), "second save is a no-op");
        let loaded = store.load(&key, &arch).unwrap();
        assert_eq!(pre, loaded);
        assert_eq!(store.entries().len(), 1);
        assert_eq!(store.clear(), 1);
        assert!(store.entries().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn describe_names_version_and_key() {
        let dir = scratch("describe");
        let store = DiskStore::open(&dir).unwrap();
        let (key, pre, _) = baked(false);
        store.save(&key, &pre).unwrap();
        let line = DiskStore::describe(&store.entries()[0]).unwrap();
        assert!(line.contains("v1.4"), "{line}");
        assert!(line.contains("TN"), "{line}");
        assert!(line.contains("shard 0/1"), "{line}");
        // A plain save carries zero provenance and timing and the
        // listing stays quiet about both.
        assert!(!line.contains("deltas"), "{line}");
        assert!(!line.contains("compiled"), "{line}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_artifacts_persist_under_distinct_files() {
        let dir = scratch("shard");
        let store = DiskStore::open(&dir).unwrap();
        let (key, pre, arch) = baked(false);
        let k0 = key.with_shard(0, 2);
        let k1 = key.with_shard(1, 2);
        assert_ne!(store.path_of(&k0), store.path_of(&k1));
        assert_ne!(store.path_of(&key), store.path_of(&k0));
        assert!(store.save(&k0, &pre).unwrap());
        assert!(store.save(&k1, &pre).unwrap());
        assert_eq!(store.entries().len(), 2);
        assert_eq!(store.load(&k0, &arch).unwrap(), pre);
        // A differently-stamped key never serves another shard's file.
        assert!(matches!(store.load(&key, &arch), Err(StoreError::Missing)));
        let lines: Vec<String> = store
            .entries()
            .iter()
            .map(|p| DiskStore::describe(p).unwrap())
            .collect();
        assert!(lines.iter().any(|l| l.contains("shard 0/2")), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("shard 1/2")), "{lines:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn provenance_round_trips_and_shows_in_describe() {
        let dir = scratch("prov");
        let store = DiskStore::open(&dir).unwrap();
        let (key, pre, arch) = baked(false);
        let prov = DeltaProvenance { batches: 3, dirty_partitions: 7, patched_ops: 41 };
        let timing = PreprocessTiming {
            partition_ns: 2_000_000,
            rank_ns: 1_000_000,
            tables_ns: 500_000,
            plan_ns: 1_500_000,
            threads: 4,
        };
        assert!(store.save_with(&key, &pre, &prov, &timing).unwrap());
        let (loaded, got, t) = store.load_with(&key, &arch).unwrap();
        assert_eq!(pre, loaded);
        assert_eq!(prov, got);
        assert_eq!(timing, t);
        // Plain `load` still works and simply drops the stamps.
        assert_eq!(pre, store.load(&key, &arch).unwrap());
        let line = DiskStore::describe(&store.entries()[0]).unwrap();
        assert!(line.contains("deltas 3 (7 dirty, 41 ops)"), "{line}");
        assert!(line.contains("compiled 5000us on 4 thread(s)"), "{line}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
