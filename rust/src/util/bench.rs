//! Minimal criterion-style bench harness (the offline image vendors no
//! criterion). Used by every `benches/*.rs` target via `harness = false`:
//! warmup, repeated timed runs, mean/min/max/stddev report, and a
//! black-box to defeat dead-code elimination.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-exported black box.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Workload-derived throughput attached to a stat by
/// [`Bench::annotate_throughput`]: how much graph work one iteration of
/// the measured closure performed, divided by its mean time. Tracked in
/// the `BENCH_*.json` trajectory so hot-path wins read as rates, not
/// just durations.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    pub edges_per_sec: f64,
    pub supersteps_per_sec: f64,
}

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
    /// Optional throughput annotation (see [`Throughput`]).
    pub throughput: Option<Throughput>,
}

impl Stats {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12} mean  [{:>12} .. {:>12}]  ±{:<10} ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.min),
            fmt_dur(self.max),
            fmt_dur(self.stddev),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Bench runner: fixed warmup iterations, then timed iterations chosen to
/// fill roughly `target` wall time (bounded by `max_iters`).
pub struct Bench {
    warmup: usize,
    target: Duration,
    max_iters: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 1,
            target: Duration::from_secs(2),
            max_iters: 50,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_target(mut self, target: Duration) -> Self {
        self.target = target;
        self
    }

    pub fn with_max_iters(mut self, n: usize) -> Self {
        self.max_iters = n.max(1);
        self
    }

    /// Measure `f`, printing the stats line immediately.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        // Estimate a single-iteration time to size the loop.
        let t0 = Instant::now();
        black_box(f());
        let est = t0.elapsed().max(Duration::from_nanos(100));
        let iters = ((self.target.as_secs_f64() / est.as_secs_f64()).ceil() as usize)
            .clamp(3, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed());
        }
        let sum: Duration = samples.iter().sum();
        let mean = sum / iters as u32;
        let min = *samples.iter().min().unwrap();
        let max = *samples.iter().max().unwrap();
        let var = samples
            .iter()
            .map(|s| {
                let d = s.as_secs_f64() - mean.as_secs_f64();
                d * d
            })
            .sum::<f64>()
            / iters as f64;
        let stats = Stats {
            name: name.to_string(),
            iters,
            mean,
            min,
            max,
            stddev: Duration::from_secs_f64(var.sqrt()),
            throughput: None,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Attach edge/superstep throughput to the most recent result:
    /// `edges` and `supersteps` are the work performed by **one**
    /// iteration of the measured closure; rates are computed against its
    /// mean time and land in the JSON trajectory.
    pub fn annotate_throughput(&mut self, edges: u64, supersteps: u64) {
        if let Some(r) = self.results.last_mut() {
            let secs = r.mean.as_secs_f64().max(1e-12);
            let t = Throughput {
                edges_per_sec: edges as f64 / secs,
                supersteps_per_sec: supersteps as f64 / secs,
            };
            println!(
                "  -> {:.2} M edges/s, {:.0} supersteps/s",
                t.edges_per_sec / 1e6,
                t.supersteps_per_sec
            );
            r.throughput = Some(t);
        }
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Serialize every measured stat as a JSON array (hand-rolled — the
    /// offline image vendors no serde). Durations are nanoseconds.
    pub fn to_json(&self) -> String {
        let mut s = String::from("[\n");
        for (i, r) in self.results.iter().enumerate() {
            if i > 0 {
                s.push_str(",\n");
            }
            s.push_str(&format!(
                "  {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {}, \
                 \"min_ns\": {}, \"max_ns\": {}, \"stddev_ns\": {}",
                r.name.replace('\\', "\\\\").replace('"', "\\\""),
                r.iters,
                r.mean.as_nanos(),
                r.min.as_nanos(),
                r.max.as_nanos(),
                r.stddev.as_nanos(),
            ));
            if let Some(t) = r.throughput {
                s.push_str(&format!(
                    ", \"edges_per_sec\": {:.1}, \"supersteps_per_sec\": {:.1}",
                    t.edges_per_sec, t.supersteps_per_sec
                ));
            }
            s.push('}');
        }
        s.push_str("\n]\n");
        s
    }

    /// Write [`to_json`](Self::to_json) to `path` (`BENCH_*.json` files
    /// tracked per bench target).
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new()
            .with_target(Duration::from_millis(5))
            .with_max_iters(5);
        let s = b.run("noop-loop", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(s.iters >= 3);
        assert!(s.mean >= s.min && s.mean <= s.max);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }

    #[test]
    fn json_output_has_one_record_per_result() {
        let mut b = Bench::new()
            .with_target(Duration::from_millis(1))
            .with_max_iters(3);
        b.run("a \"quoted\" name", || black_box(1 + 1));
        b.run("plain", || black_box(2 + 2));
        let json = b.to_json();
        assert_eq!(json.matches("\"name\"").count(), 2);
        assert!(json.contains("a \\\"quoted\\\" name"));
        assert!(json.contains("\"mean_ns\""));
        assert!(json.trim_start().starts_with('['));
        assert!(json.trim_end().ends_with(']'));
        // No annotation, no throughput fields.
        assert!(!json.contains("edges_per_sec"));
    }

    #[test]
    fn throughput_annotation_lands_in_json() {
        let mut b = Bench::new()
            .with_target(Duration::from_millis(1))
            .with_max_iters(3);
        b.run("annotated", || black_box(1 + 1));
        b.annotate_throughput(1_000, 10);
        let s = b.results().last().unwrap();
        let t = s.throughput.expect("annotated");
        assert!(t.edges_per_sec > 0.0);
        assert!(t.supersteps_per_sec > 0.0);
        assert!((t.edges_per_sec / t.supersteps_per_sec - 100.0).abs() < 1e-6);
        let json = b.to_json();
        assert!(json.contains("\"edges_per_sec\""));
        assert!(json.contains("\"supersteps_per_sec\""));
    }
}
