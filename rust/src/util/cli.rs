//! Tiny argument parser (the offline image vendors no clap): positional
//! arguments plus `--flag`, `--key value` and `--key=value` options.

use std::collections::HashMap;

use anyhow::Result;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `flag_names` lists options that
    /// take no value.
    pub fn parse(raw: impl IntoIterator<Item = String>, flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("option --{name} expects a value"))?;
                    out.options.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name} {s:?}: {e}")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(name)?.unwrap_or(default))
    }

    /// Option as a filesystem path (`--artifact-dir DIR` and friends) —
    /// unlike [`get_parsed`](Self::get_parsed), never trips over
    /// non-UTF-8-unfriendly characters `FromStr` impls reject.
    pub fn get_path(&self, name: &str) -> Option<std::path::PathBuf> {
        self.get(name).map(std::path::PathBuf::from)
    }

    /// Like [`get_path`](Self::get_path) but required: a missing option
    /// is an error naming the flag.
    pub fn require_path(&self, name: &str) -> Result<std::path::PathBuf> {
        self.get_path(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name} <DIR>"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["validate", "verbose"]).unwrap()
    }

    #[test]
    fn parses_positional_and_options() {
        let a = args("run WV --algo bfs --scale=0.5 --validate");
        assert_eq!(a.positional, vec!["run", "WV"]);
        assert_eq!(a.get("algo"), Some("bfs"));
        assert_eq!(a.get_or("scale", 1.0f64).unwrap(), 0.5);
        assert!(a.flag("validate"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn typed_parsing_errors() {
        let a = args("--engines abc");
        assert!(a.get_parsed::<u32>("engines").is_err());
        assert_eq!(args("--engines 8").get_or("engines", 32u32).unwrap(), 8);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--scale".to_string()], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = args("");
        assert_eq!(a.get_or("engines", 32u32).unwrap(), 32);
        assert!(a.get_parsed::<f64>("scale").unwrap().is_none());
    }

    #[test]
    fn paths_parse_and_require() {
        let a = args("artifacts warm TN --artifact-dir /tmp/cache");
        assert_eq!(
            a.require_path("artifact-dir").unwrap(),
            std::path::PathBuf::from("/tmp/cache")
        );
        assert!(a.get_path("nope").is_none());
        assert!(a.require_path("nope").unwrap_err().to_string().contains("--nope"));
    }
}
