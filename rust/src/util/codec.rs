//! Hand-rolled little-endian framing primitives for the on-disk artifact
//! format (`session::store`).
//!
//! The offline image vendors no serde, and the persisted
//! [`Preprocessed`](crate::accel::Preprocessed) artifact must stay
//! byte-stable across builds anyway (content-addressed cache files are
//! diffed and shipped to CI), so the encoding is explicit: fixed-width
//! little-endian scalars, `u64` length-prefixed slices, no padding, no
//! implementation-defined layout. Every multi-byte value is LE regardless
//! of host endianness.
//!
//! [`Reader`] is panic-free by construction: every read is bounds-checked
//! and returns a typed [`CodecError`], and slice reads validate
//! `len × size ≤ remaining` *before* allocating, so a corrupt or
//! truncated length prefix can neither panic nor trigger an absurd
//! allocation.

use std::fmt;

/// Decode failure. `Truncated` = ran off the end of the buffer (or a
/// length prefix promises more bytes than remain); `Invalid` = bytes were
/// present but violate a structural invariant of the type being decoded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecError {
    Truncated,
    Invalid(&'static str),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Truncated => write!(f, "unexpected end of input"),
            CodecError::Invalid(what) => write!(f, "invalid encoding: {what}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// Append-only little-endian encoder.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw bytes, no length prefix (fixed-size fields like magic).
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// `u32` length-prefixed UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v.as_bytes());
    }

    /// `u64` length-prefixed `u32` slice.
    pub fn put_u32s(&mut self, v: &[u32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u32(x);
        }
    }

    /// `u64` length-prefixed `u64` slice.
    pub fn put_u64s(&mut self, v: &[u64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u64(x);
        }
    }

    /// `u64` length-prefixed `f32` slice (bit patterns preserved exactly).
    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_f32(x);
        }
    }
}

/// Bounds-checked little-endian decoder over a borrowed buffer.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Error unless every byte has been consumed — trailing garbage in a
    /// cache file is corruption, not padding.
    pub fn done(&self) -> Result<(), CodecError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(CodecError::Invalid("trailing bytes"))
        }
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.bytes(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String, CodecError> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| CodecError::Invalid("non-UTF-8 string"))
    }

    /// Read a `u64` length prefix promising `n` records of at least
    /// `min_record_size` bytes each, validated against the remaining
    /// bytes **before** any allocation: a corrupt prefix can neither
    /// panic nor trigger an absurd allocation. Record decoders share
    /// this with the typed slice readers below — the one place the
    /// guard lives.
    pub fn prefixed_count(&mut self, min_record_size: usize) -> Result<usize, CodecError> {
        let n = self.u64()?;
        let total = (n as usize)
            .checked_mul(min_record_size)
            .ok_or(CodecError::Truncated)?;
        if total > self.remaining() {
            return Err(CodecError::Truncated);
        }
        Ok(n as usize)
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>, CodecError> {
        let n = self.prefixed_count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>, CodecError> {
        let n = self.prefixed_count(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u64()?);
        }
        Ok(v)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.prefixed_count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }
}

/// FNV-1a 64-bit over `bytes` — the file integrity checksum. Non-crypto
/// (the cache directory is a trust boundary the filesystem already
/// enforces); what it must catch is truncation, bit rot, and partial
/// writes, and it is stable across platforms and builds.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip_is_little_endian() {
        let mut w = Writer::new();
        w.put_u8(0xAB);
        w.put_u32(0x1122_3344);
        w.put_u64(0x5566_7788_99AA_BBCC);
        w.put_f32(-1.5);
        // Explicit LE layout: u32 low byte first.
        assert_eq!(&w.as_bytes()[1..5], &[0x44, 0x33, 0x22, 0x11]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 0xAB);
        assert_eq!(r.u32().unwrap(), 0x1122_3344);
        assert_eq!(r.u64().unwrap(), 0x5566_7788_99AA_BBCC);
        assert_eq!(r.f32().unwrap(), -1.5);
        r.done().unwrap();
    }

    #[test]
    fn slices_and_strings_roundtrip() {
        let mut w = Writer::new();
        w.put_str("artifact");
        w.put_u32s(&[1, 2, 3]);
        w.put_u64s(&[u64::MAX]);
        w.put_f32s(&[0.5, f32::INFINITY]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.str().unwrap(), "artifact");
        assert_eq!(r.u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.u64s().unwrap(), vec![u64::MAX]);
        let f = r.f32s().unwrap();
        assert_eq!(f[0], 0.5);
        assert!(f[1].is_infinite());
        r.done().unwrap();
    }

    #[test]
    fn truncation_is_typed_not_a_panic() {
        let mut w = Writer::new();
        w.put_u32s(&[7; 10]);
        let bytes = w.into_bytes();
        // Cut mid-slice: the length prefix promises more than remains.
        let mut r = Reader::new(&bytes[..bytes.len() / 2]);
        assert_eq!(r.u32s().unwrap_err(), CodecError::Truncated);
        // Scalar off the end.
        let mut r = Reader::new(&[0u8; 3]);
        assert_eq!(r.u32().unwrap_err(), CodecError::Truncated);
    }

    #[test]
    fn absurd_length_prefix_rejected_before_allocation() {
        let mut w = Writer::new();
        w.put_u64(u64::MAX); // claims u64::MAX elements
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.f32s().unwrap_err(), CodecError::Truncated);
    }

    #[test]
    fn trailing_bytes_are_invalid() {
        let mut r = Reader::new(&[1, 2]);
        r.u8().unwrap();
        assert!(matches!(r.done(), Err(CodecError::Invalid(_))));
    }

    #[test]
    fn fnv_is_stable_and_sensitive() {
        // Reference FNV-1a vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a64(b"artifact"), fnv1a64(b"artifacu"));
    }
}
