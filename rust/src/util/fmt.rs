//! Human-readable formatting of physical quantities for reports.

/// Format an energy value given in joules with an SI prefix (J, mJ, µJ, nJ, pJ).
pub fn energy(joules: f64) -> String {
    si(joules, "J")
}

/// Format a time value given in seconds with an SI prefix.
pub fn time(seconds: f64) -> String {
    if seconds >= 31_536_000.0 {
        return format!("{:.1} years", seconds / 31_536_000.0);
    }
    if seconds >= 3_600.0 {
        return format!("{:.1} h", seconds / 3_600.0);
    }
    si(seconds, "s")
}

/// Format a count with thousands separators.
pub fn count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

fn si(v: f64, unit: &str) -> String {
    let a = v.abs();
    let (scale, prefix) = if a == 0.0 {
        (1.0, "")
    } else if a >= 1.0 {
        (1.0, "")
    } else if a >= 1e-3 {
        (1e3, "m")
    } else if a >= 1e-6 {
        (1e6, "µ")
    } else if a >= 1e-9 {
        (1e9, "n")
    } else {
        (1e12, "p")
    };
    let scaled = v * scale;
    if scaled >= 100.0 {
        format!("{scaled:.0} {prefix}{unit}")
    } else if scaled >= 10.0 {
        format!("{scaled:.1} {prefix}{unit}")
    } else {
        format!("{scaled:.2} {prefix}{unit}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_prefixes() {
        assert_eq!(energy(4.1), "4.10 J");
        assert_eq!(energy(3.3e-3), "3.30 mJ");
        assert_eq!(energy(5.9e-6), "5.90 µJ");
        assert_eq!(energy(1.1e-12), "1.10 pJ");
    }

    #[test]
    fn time_scales() {
        assert_eq!(time(2.0e-9), "2.00 ns");
        assert!(time(7200.0).contains('h'));
        assert!(time(4.0e8).contains("years"));
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1_000), "1,000");
        assert_eq!(count(1_234_567), "1,234,567");
    }
}
