//! Fast non-cryptographic hasher (FxHash-style multiply-xor; the offline
//! image vendors no fxhash/ahash crate).
//!
//! PERF NOTE (EXPERIMENTS.md §Perf iteration 2a): swapping this in for
//! the window-partition and ranking maps measured ~2x SLOWER than std's
//! hasher on the structured `(brow << 32) | bcol` keys (clustered low
//! bits after the multiply defeat hashbrown's bucket indexing), so the
//! hot paths keep `std::collections::HashMap`. Retained as a utility and
//! as the recorded negative result.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher over 8-byte chunks (Firefox's FxHash constant).
#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn mix(&mut self, word: u64) {
        self.state = (self.state.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.mix(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.mix(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.mix(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.mix(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Drop-in `HashMap` with the fast hasher.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_keys_distinct_buckets_mostly() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..10_000u64 {
            m.insert(i.wrapping_mul(0x9E3779B97F4A7C15), i);
        }
        assert_eq!(m.len(), 10_000);
        for i in 0..10_000u64 {
            assert_eq!(m[&i.wrapping_mul(0x9E3779B97F4A7C15)], i);
        }
    }

    #[test]
    fn hasher_is_deterministic() {
        let mut a = FxHasher::default();
        let mut b = FxHasher::default();
        a.write_u64(42);
        b.write_u64(42);
        assert_eq!(a.finish(), b.finish());
        let mut c = FxHasher::default();
        c.write_u64(43);
        assert_ne!(a.finish(), c.finish());
    }

    #[test]
    fn byte_writes_cover_tail_chunks() {
        let mut a = FxHasher::default();
        a.write(b"hello world tail");
        let mut b = FxHasher::default();
        b.write(b"hello world tai_");
        assert_ne!(a.finish(), b.finish());
    }
}
