//! Small shared utilities: deterministic RNG, formatting helpers.

pub mod bench;
pub mod cli;
pub mod codec;
pub mod hash;
pub mod fmt;
pub mod rng;

pub use rng::SplitMix64;
