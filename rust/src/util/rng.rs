//! Deterministic, dependency-free PRNG.
//!
//! All synthetic datasets and randomized workloads are seeded, so every
//! figure/table regenerates bit-identically across runs (a requirement for
//! the per-experiment index in DESIGN.md). SplitMix64 is statistically
//! strong enough for graph generation and trivially portable.

/// SplitMix64 PRNG (Steele et al., "Fast splittable pseudorandom number
/// generators", OOPSLA 2014). Passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, bias-free enough
    /// for graph generation; exact rejection not needed here).
    #[inline]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_bounded(bound as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fork an independent stream (for parallel generators).
    pub fn fork(&mut self) -> Self {
        Self::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn bounded_respects_bound() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            assert!(r.next_bounded(13) < 13);
        }
    }

    #[test]
    fn bounded_hits_all_residues() {
        let mut r = SplitMix64::new(11);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.next_bounded(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mean_is_near_half() {
        let mut r = SplitMix64::new(3);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
