//! Round-trip + differential loaded-plan suite — the lockdown for the
//! persistent on-disk artifact cache (`session::store`).
//!
//! Three contracts:
//!
//! 1. **Round trip**: `save(pre) → load` yields an artifact whose every
//!    public accessor — plan ops, groups, slot candidates, lane tables,
//!    gather table, static config, interned pattern table, executor
//!    operands — equals the in-memory one, for random graphs × all four
//!    algorithms × randomized architectures.
//! 2. **Determinism extended to loaded plans**: a deserialized plan's
//!    [`RunResult`] is **bit-identical** to the in-memory plan's under
//!    the sequential interpreter, the scoped-spawn mechanism, and the
//!    persistent worker pool, and feeds the DSE static-slot rebuild
//!    identically.
//! 3. **Negative paths are typed, never panics**: truncation, flipped
//!    bytes, stale versions, and architecture mismatches each produce a
//!    typed [`StoreError`], and the two-tier [`ArtifactStore`] falls back
//!    to recompute (and repairs the file) instead of serving a corrupt
//!    plan. Disk publishes are exactly-once across racing stores.

use std::sync::Arc;

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::traits::VertexProgram;
use repro::algo::{Bfs, PageRank, Sssp, Wcc};
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::sched::executor::NativeExecutor;
use repro::sched::{run_parallel_pooled, run_parallel_scoped, WorkerPool};
use repro::session::{
    ArtifactKey, ArtifactStore, DiskStore, JobSpec, Session, StoreError, FORMAT_VERSION,
};
use repro::util::codec::fnv1a64;
use repro::util::SplitMix64;

mod common;
use common::{
    assert_bit_identical, random_arch, random_graph, scratch_dir, with_random_weights,
};

/// A disposable key for graphs that don't come from a `Dataset` preset:
/// the key's dataset/scale identity is irrelevant to (de)serialization
/// fidelity, which is what these tests exercise; only the arch part must
/// be honest because `load` verifies `plan.matches`.
fn test_key(seed: u64, weighted: bool, arch: &ArchConfig) -> ArtifactKey {
    let scale = 1.0 - (seed % 7) as f64 * 1e-3;
    ArtifactKey::new(Dataset::Tiny, scale, weighted, arch)
}

#[test]
fn prop_roundtrip_preserves_every_public_accessor() {
    for seed in 500..506u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0xA21F);
        let arch = random_arch(&mut rng);
        let gw = with_random_weights(&g, &mut rng);
        for (graph, weighted) in [(&g, false), (&gw, true)] {
            let acc = Accelerator::new(arch.clone(), CostParams::default());
            let pre = acc.preprocess(graph, weighted).unwrap();
            let dir = scratch_dir("roundtrip");
            let store = DiskStore::open(&dir).unwrap();
            let key = test_key(seed, weighted, &arch);
            assert!(store.save(&key, &pre).unwrap(), "seed {seed}: first save writes");
            let got = store.load(&key, &arch).unwrap();
            let ctx = format!("seed {seed} weighted {weighted} arch {arch:?}");

            // Whole-struct equality first (catches anything the explicit
            // accessor walk below might miss)…
            assert_eq!(pre.part, got.part, "{ctx}: Partitioned");
            assert_eq!(pre.ranking, got.ranking, "{ctx}: PatternRanking");
            assert_eq!(pre.ct, got.ct, "{ctx}: ConfigTable");
            assert_eq!(pre.st, got.st, "{ctx}: SubgraphTable");
            assert_eq!(pre.plan, got.plan, "{ctx}: ExecutionPlan");
            assert_eq!(pre, got, "{ctx}: Preprocessed");

            // …then the public plan accessors, one by one, the way the
            // interpreter and executors actually consume them.
            let (a, b) = (&pre.plan, &got.plan);
            assert_eq!(a.num_ops(), b.num_ops(), "{ctx}: num_ops");
            assert_eq!(a.num_groups(), b.num_groups(), "{ctx}: num_groups");
            for grp in 0..a.num_groups() {
                assert_eq!(a.group_bounds(grp), b.group_bounds(grp), "{ctx}: group {grp}");
            }
            assert_eq!(a.static_config(), b.static_config(), "{ctx}: static_config");
            assert_eq!(a.lanes(), b.lanes(), "{ctx}: lane table");
            assert_eq!(a.gather(), b.gather(), "{ctx}: gather table");
            assert_eq!(a.out_degrees(), b.out_degrees(), "{ctx}: out_degrees");
            assert!(b.matches(&arch), "{ctx}: loaded plan must match its arch");
            for rank in 0..a.num_patterns {
                assert_eq!(
                    a.pattern_of_rank(rank),
                    b.pattern_of_rank(rank),
                    "{ctx}: pattern rank {rank}"
                );
            }
            let ids: Vec<u32> = (0..a.num_ops() as u32).collect();
            let (ba, bb) = (a.batch(&ids), b.batch(&ids));
            assert_eq!(ba.weighted(), bb.weighted(), "{ctx}: batch weighted");
            let c2 = a.c * a.c;
            let mut da = vec![0f32; c2];
            let mut db = vec![0f32; c2];
            for (k, (opa, opb)) in a.ops.iter().zip(&b.ops).enumerate() {
                assert_eq!(opa, opb, "{ctx}: op {k}");
                assert_eq!(a.slots_of(opa), b.slots_of(opb), "{ctx}: slots of op {k}");
                assert_eq!(
                    a.lanes().home_of(k),
                    b.lanes().home_of(k),
                    "{ctx}: lane home of op {k}"
                );
                assert_eq!(
                    a.gather().sources_of(k, a.c),
                    b.gather().sources_of(k, b.c),
                    "{ctx}: gather sources of op {k}"
                );
                assert_eq!(ba.bits(k), bb.bits(k), "{ctx}: bits of op {k}");
                if weighted {
                    assert_eq!(ba.weights_of(k), bb.weights_of(k), "{ctx}: weights of op {k}");
                }
                da.iter_mut().for_each(|x| *x = 0.0);
                db.iter_mut().for_each(|x| *x = 0.0);
                ba.dense_into(k, &mut da);
                bb.dense_into(k, &mut db);
                assert_eq!(da, db, "{ctx}: dense operand of op {k}");
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn prop_loaded_plan_is_bit_identical_under_all_three_mechanisms() {
    // The determinism contract extended to loaded plans: sequential
    // interpreter, scoped spawns, and the persistent pool must all
    // produce the same RunResult from a deserialized plan as from the
    // in-memory one it was saved from.
    for seed in 520..525u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0x10AD);
        let source = rng.next_bounded(g.num_vertices as u64) as u32;
        let arch = random_arch(&mut rng);
        let gw = with_random_weights(&g, &mut rng);
        let bfs = Bfs::new(source);
        let sssp = Sssp::new(source);
        let pagerank = PageRank::new(0.85, 4);
        let wcc = Wcc;
        let programs: [(&dyn VertexProgram, bool); 4] =
            [(&bfs, false), (&sssp, true), (&pagerank, false), (&wcc, false)];
        let acc = Accelerator::new(arch.clone(), CostParams::default());
        let params = CostParams::default();
        let dir = scratch_dir("mechanisms");
        let store = DiskStore::open(&dir).unwrap();
        for (program, weighted) in programs {
            let pre = acc
                .preprocess(if weighted { &gw } else { &g }, weighted)
                .unwrap();
            let key = test_key(seed, weighted, &arch);
            store.save(&key, &pre).unwrap();
            let loaded = store.load(&key, &arch).unwrap();
            let ctx = format!("seed {seed} algo {} arch {arch:?}", program.name());

            let want_seq = acc
                .run_threaded(&pre, program, &mut NativeExecutor, 1)
                .unwrap()
                .run
                .unwrap();
            let got_seq = acc
                .run_threaded(&loaded, program, &mut NativeExecutor, 1)
                .unwrap()
                .run
                .unwrap();
            assert_bit_identical(&got_seq, &want_seq, &format!("{ctx} [sequential]"));

            let want_scoped =
                run_parallel_scoped(&arch, &params, &pre.plan, program, &mut NativeExecutor, 4)
                    .unwrap();
            let got_scoped =
                run_parallel_scoped(&arch, &params, &loaded.plan, program, &mut NativeExecutor, 4)
                    .unwrap();
            assert_bit_identical(&got_scoped, &want_scoped, &format!("{ctx} [scoped]"));
            assert_bit_identical(&got_scoped, &want_seq, &format!("{ctx} [scoped vs seq]"));

            let mut pool = WorkerPool::new(4);
            for round in 0..2 {
                let got_pooled = run_parallel_pooled(
                    &arch,
                    &params,
                    &loaded.plan,
                    program,
                    &mut NativeExecutor,
                    &mut pool,
                )
                .unwrap();
                assert_bit_identical(
                    &got_pooled,
                    &want_seq,
                    &format!("{ctx} [pooled round {round}]"),
                );
            }
            store.remove(&key);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn loaded_artifact_feeds_dse_rebuild_identically() {
    // DSE sweeps call `rebuild_static_slots` on a scratch copy of the
    // artifact; a loaded artifact must sweep to the identical optimum
    // and identical per-point numbers.
    let g = Dataset::Tiny.load().unwrap();
    let arch = ArchConfig::default();
    let params = CostParams::default();
    let acc = Accelerator::new(arch.clone(), params.clone());
    let pre = acc.preprocess(&g, false).unwrap();
    let dir = scratch_dir("dse");
    let store = DiskStore::open(&dir).unwrap();
    let key = ArtifactKey::new(Dataset::Tiny, 1.0, false, &arch);
    store.save(&key, &pre).unwrap();
    let loaded = store.load(&key, &arch).unwrap();

    let program = Bfs::new(0);
    let mut scratch_a = pre.clone();
    let mut scratch_b = loaded;
    let (best_a, points_a) = repro::dse::find_best_static_split_with(
        &mut scratch_a,
        &arch,
        &params,
        &program,
        None,
    )
    .unwrap();
    let (best_b, points_b) = repro::dse::find_best_static_split_with(
        &mut scratch_b,
        &arch,
        &params,
        &program,
        None,
    )
    .unwrap();
    assert_eq!(best_a, best_b, "best split diverges");
    assert_eq!(points_a.len(), points_b.len());
    for (pa, pb) in points_a.iter().zip(&points_b) {
        assert_eq!(pa.x, pb.x);
        assert_eq!(pa.exec_time_ns, pb.exec_time_ns, "N={}: time", pa.x);
        assert_eq!(pa.energy_j, pb.energy_j, "N={}: energy", pa.x);
        assert_eq!(pa.write_bits, pb.write_bits, "N={}: writes", pa.x);
        assert_eq!(pa.static_hit_rate, pb.static_hit_rate, "N={}: hit rate", pa.x);
        assert_eq!(pa.speedup, pb.speedup, "N={}: speedup", pa.x);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Bake one Tiny artifact and return (dir, store, key, arch, bytes path).
fn baked_tiny() -> (std::path::PathBuf, DiskStore, ArtifactKey, ArchConfig) {
    let arch = ArchConfig::default();
    let acc = Accelerator::new(arch.clone(), CostParams::default());
    let g = Dataset::Tiny.load().unwrap();
    let pre = acc.preprocess(&g, false).unwrap();
    let dir = scratch_dir("negative");
    let store = DiskStore::open(&dir).unwrap();
    let key = ArtifactKey::new(Dataset::Tiny, 1.0, false, &arch);
    assert!(store.save(&key, &pre).unwrap());
    (dir, store, key, arch)
}

/// After corrupting the file, the two-tier store must recompute (typed
/// fallback, no panic), repair the on-disk entry, and a later fresh
/// store must warm-start from the repaired file.
fn assert_recovers(dir: &std::path::Path, key: ArtifactKey, what: &str) {
    let acc = Accelerator::with_defaults();
    let store = ArtifactStore::with_dir(dir).unwrap();
    let rebuilt = store.get_or_preprocess(key, &acc).unwrap();
    let s = store.stats();
    assert_eq!(s.misses, 1, "{what}: must fall back to recompute");
    assert_eq!(s.disk_misses, 1, "{what}: the bad file is a disk miss");
    assert_eq!(s.writes, 1, "{what}: the repaired artifact is rewritten");

    let warm = ArtifactStore::with_dir(dir).unwrap();
    let loaded = warm.get_or_preprocess(key, &acc).unwrap();
    let s = warm.stats();
    assert_eq!((s.misses, s.disk_hits), (0, 1), "{what}: repair must stick");
    assert_eq!(*rebuilt, *loaded, "{what}: repaired artifact diverges");
}

#[test]
fn truncated_file_is_typed_and_recomputed() {
    let (dir, store, key, arch) = baked_tiny();
    let path = store.path_of(&key);
    let bytes = std::fs::read(&path).unwrap();
    for cut in [0usize, 7, 11, bytes.len() / 2, bytes.len() - 1] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = store.load(&key, &arch).unwrap_err();
        // Cuts inside the fixed header are length errors; cuts inside the
        // payload surface as a failed checksum over the shortened body.
        // Both are typed, neither panics, neither ever yields a plan.
        assert!(
            matches!(err, StoreError::Truncated | StoreError::Checksum),
            "cut at {cut}: unexpected {err:?}"
        );
    }
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert_recovers(&dir, key, "truncated");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flipped_bytes_fail_the_checksum() {
    let (dir, store, key, arch) = baked_tiny();
    let path = store.path_of(&key);
    let clean = std::fs::read(&path).unwrap();
    // A flipped checksum byte (the ISSUE's literal case), a flipped
    // payload byte, and a flipped key byte must all be caught.
    for pos in [clean.len() - 1, clean.len() / 2, 20] {
        let mut bad = clean.clone();
        bad[pos] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let err = store.load(&key, &arch).unwrap_err();
        assert!(matches!(err, StoreError::Checksum), "flip at {pos}: unexpected {err:?}");
    }
    assert_recovers(&dir, key, "checksum flip");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_versions_are_typed_and_recomputed() {
    let (dir, store, key, arch) = baked_tiny();
    let path = store.path_of(&key);
    let clean = std::fs::read(&path).unwrap();

    // Stale envelope format (bytes 8..12): detected before the checksum.
    let mut stale = clean.clone();
    stale[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
    std::fs::write(&path, &stale).unwrap();
    match store.load(&key, &arch).unwrap_err() {
        StoreError::FormatVersion { found } => assert_eq!(found, FORMAT_VERSION + 1),
        other => panic!("unexpected {other:?}"),
    }

    // Stale payload schema (bytes 12..16) with a *recomputed* checksum —
    // a well-formed file from a binary with a different schema.
    let mut stale = clean.clone();
    stale[12..16].copy_from_slice(&(repro::session::SCHEMA_VERSION + 1).to_le_bytes());
    let body_len = stale.len() - 8;
    let sum = fnv1a64(&stale[..body_len]);
    stale[body_len..].copy_from_slice(&sum.to_le_bytes());
    std::fs::write(&path, &stale).unwrap();
    match store.load(&key, &arch).unwrap_err() {
        StoreError::SchemaVersion { found } => {
            assert_eq!(found, repro::session::SCHEMA_VERSION + 1)
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_recovers(&dir, key, "stale schema");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn arch_mismatch_is_typed_and_recomputed() {
    let (dir, store, key, _arch) = baked_tiny();
    // Same dataset, different static split: a different key, hence a
    // different filename. Copy the existing artifact onto the other
    // key's path — the embedded key bytes must still unmask it.
    let arch_b = ArchConfig { static_engines: 4, ..ArchConfig::default() };
    let key_b = ArtifactKey::new(Dataset::Tiny, 1.0, false, &arch_b);
    std::fs::copy(store.path_of(&key), store.path_of(&key_b)).unwrap();
    let err = store.load(&key_b, &arch_b).unwrap_err();
    assert!(matches!(err, StoreError::KeyMismatch), "unexpected {err:?}");

    // The two-tier store recomputes (and repairs) for the mismatched key…
    let acc_b = Accelerator::new(arch_b.clone(), CostParams::default());
    let two_tier = ArtifactStore::with_dir(&dir).unwrap();
    two_tier.get_or_preprocess(key_b, &acc_b).unwrap();
    let s = two_tier.stats();
    assert_eq!((s.misses, s.disk_misses, s.writes), (1, 1, 1), "mismatch must recompute");
    // …while the original key's artifact still disk-hits.
    let acc = Accelerator::with_defaults();
    two_tier.get_or_preprocess(key, &acc).unwrap();
    assert_eq!(two_tier.stats().disk_hits, 1, "original artifact untouched");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn garbage_files_never_panic() {
    let (dir, store, key, arch) = baked_tiny();
    let path = store.path_of(&key);
    let mut rng = SplitMix64::new(0xBAD);
    for len in [0usize, 1, 8, 64, 4096] {
        let junk: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        std::fs::write(&path, &junk).unwrap();
        assert!(store.load(&key, &arch).is_err(), "len {len}: junk must not load");
    }
    assert_recovers(&dir, key, "garbage");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn two_store_disk_stampede_publishes_exactly_once() {
    // Two independent stores (e.g. two serve processes) sharing one cold
    // directory: every thread gets a coherent artifact, and exactly one
    // write reaches the disk across all of them.
    let dir = scratch_dir("stampede");
    let store_a = Arc::new(ArtifactStore::with_dir(&dir).unwrap());
    let store_b = Arc::new(ArtifactStore::with_dir(&dir).unwrap());
    let arch = ArchConfig::default();
    let key = ArtifactKey::new(Dataset::Tiny, 1.0, false, &arch);
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let store = if i % 2 == 0 { Arc::clone(&store_a) } else { Arc::clone(&store_b) };
            std::thread::spawn(move || {
                store
                    .get_or_preprocess(key, &Accelerator::with_defaults())
                    .unwrap()
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &results {
        assert_eq!(**r, *results[0], "stampede readers must agree");
    }
    let sa = store_a.stats();
    let sb = store_b.stats();
    // Each store compiles at most once (per-key slot coalescing); a
    // store may even compile zero times if the other published to disk
    // before its first probe — but *somebody* compiled, every request
    // was answered from a compile or a disk hit…
    assert!(sa.misses <= 1 && sb.misses <= 1, "per-store coalescing: {sa:?} {sb:?}");
    assert!(sa.misses + sb.misses >= 1, "somebody must compile: {sa:?} {sb:?}");
    assert_eq!(
        sa.misses + sb.misses + sa.disk_hits + sb.disk_hits,
        2,
        "each store resolves its key exactly once beyond memory: {sa:?} {sb:?}"
    );
    // …and the disk sees exactly one publish across both.
    assert_eq!(sa.writes + sb.writes, 1, "exactly-once on-disk write");
    assert_eq!(DiskStore::open(&dir).unwrap().entries().len(), 1);

    // A third store warm-starts without compiling anything.
    let store_c = ArtifactStore::with_dir(&dir).unwrap();
    let c = store_c.get_or_preprocess(key, &Accelerator::with_defaults()).unwrap();
    let s = store_c.stats();
    assert_eq!((s.misses, s.disk_hits), (0, 1));
    assert_eq!(*c, *results[0]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_session_runs_with_zero_plan_compilations() {
    // The acceptance criterion end to end: a session started over a warm
    // artifact directory serves all four algorithms, at several thread
    // counts, with zero plan compilations and reports bit-identical to a
    // cold in-memory session.
    let dir = scratch_dir("warm-session");
    let specs = |p: usize| {
        vec![
            JobSpec::new(Dataset::Tiny, "bfs").with_source(3).with_parallelism(p),
            JobSpec::new(Dataset::Tiny, "sssp").with_source(1).with_parallelism(p),
            JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(4).with_parallelism(p),
            JobSpec::new(Dataset::Tiny, "wcc").with_parallelism(p),
        ]
    };

    // Pass 1 (cold, persisting): compiles once per key and writes.
    let cold = Session::builder().artifact_dir(&dir).build().unwrap();
    let cold_reports: Vec<_> = specs(1).iter().map(|s| cold.run(s).unwrap()).collect();
    let s = cold.artifacts().stats();
    assert_eq!(s.misses, 2, "one unweighted + one weighted key");
    assert_eq!(s.writes, 2);
    drop(cold);

    // Pass 2 (warm, a "restarted fleet"): zero compilations, and every
    // report — across sequential and pooled parallel execution — is
    // bit-identical to the cold pass.
    let warm = Session::builder().artifact_dir(&dir).build().unwrap();
    for threads in [1usize, 2, 4] {
        for (spec, want) in specs(threads).iter().zip(&cold_reports) {
            let got = warm.run(spec).unwrap();
            let ctx = format!("threads {threads} algo {}", got.algorithm);
            assert_bit_identical(
                got.run.as_ref().unwrap(),
                want.run.as_ref().unwrap(),
                &ctx,
            );
            assert_eq!(got.counts, want.counts, "{ctx}: counts");
            assert_eq!(got.exec_time_ns, want.exec_time_ns, "{ctx}: time");
            assert_eq!(got.static_hit_rate, want.static_hit_rate, "{ctx}: hit rate");
        }
    }
    let s = warm.artifacts().stats();
    assert_eq!(s.misses, 0, "warm start must compile nothing");
    assert_eq!(s.disk_hits, 2, "both keys load from disk");
    assert_eq!(s.writes, 0, "nothing new to persist");
    let _ = std::fs::remove_dir_all(&dir);
}
