//! Helpers shared by the integration-test binaries.

use repro::algo::traits::INF;

/// Elementwise tolerance comparison treating any pair of values at or
/// above the INF sentinel as equal (unreached vertices).
pub fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if *g >= INF && *w >= INF {
            continue;
        }
        assert!((g - w).abs() <= tol, "{what}: vertex {i}: got {g}, want {w}");
    }
}
