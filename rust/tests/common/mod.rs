//! Helpers shared by the integration-test binaries. Each binary uses its
//! own subset, so the module allows dead code as a whole.
#![allow(dead_code)]

use repro::algo::traits::INF;
use repro::graph::coo::{Coo, Edge};
use repro::graph::generator::{erdos_renyi, rmat, RmatParams};
use repro::util::SplitMix64;

/// Elementwise tolerance comparison treating any pair of values at or
/// above the INF sentinel as equal (unreached vertices).
pub fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if *g >= INF && *w >= INF {
            continue;
        }
        assert!((g - w).abs() <= tol, "{what}: vertex {i}: got {g}, want {w}");
    }
}

/// Seeded random graph for property sweeps: 32–512 vertices, R-MAT or
/// Erdős–Rényi, average degree 1–8. Every assertion over one should print
/// the seed (`"seed {seed}: ..."`) so failures are reproducible.
pub fn random_graph(seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    let n = 32 + rng.next_bounded(480) as u32;
    let m = (n as usize) * (1 + rng.next_index(8));
    if rng.next_bool(0.5) {
        rmat(n, m, RmatParams::default(), rng.next_u64())
    } else {
        erdos_renyi(n, m, rng.next_u64())
    }
}

/// Same topology with seeded random edge weights in [0.5, 4.5) — the
/// SSSP cases need real weight data.
pub fn with_random_weights(g: &Coo, rng: &mut SplitMix64) -> Coo {
    Coo::from_edges(
        g.num_vertices,
        g.edges
            .iter()
            .map(|e| Edge::weighted(e.src, e.dst, 0.5 + rng.next_f32() * 4.0))
            .collect(),
    )
}

/// Seeded random [`DeltaBatch`](repro::graph::DeltaBatch) valid against
/// `g`: removes and reweights target existing edges, adds target
/// rejection-sampled absent pairs, so the batch always applies cleanly.
/// Category overlap is impossible (adds are absent pairs, the rest are
/// present pairs) and same-pair repeats collapse under the batch's
/// last-wins dedup — the result is valid by construction.
pub fn random_delta_batch(g: &Coo, rng: &mut SplitMix64) -> repro::graph::DeltaBatch {
    use repro::graph::{DeltaBatch, EdgeDelta};
    let mut deltas = Vec::new();
    for _ in 0..1 + rng.next_index(6) {
        let e = g.edges[rng.next_index(g.edges.len())];
        if rng.next_bool(0.5) {
            deltas.push(EdgeDelta::remove(e.src, e.dst));
        } else {
            deltas.push(EdgeDelta::reweight(e.src, e.dst, 0.5 + rng.next_f32() * 4.0));
        }
    }
    for _ in 0..1 + rng.next_index(6) {
        // Rejection sampling; these graphs are sparse, so a valid pair
        // lands almost immediately (the cap only guards a pathological
        // near-complete graph).
        for _ in 0..64 {
            let src = rng.next_bounded(g.num_vertices as u64) as u32;
            let dst = rng.next_bounded(g.num_vertices as u64) as u32;
            let present = g
                .edges
                .binary_search_by_key(&(src, dst), |e| (e.src, e.dst))
                .is_ok();
            if src != dst && !present {
                deltas.push(EdgeDelta::add_weighted(src, dst, 0.5 + rng.next_f32() * 4.0));
                break;
            }
        }
    }
    DeltaBatch::new(g.num_vertices, deltas).expect("constructed deltas are valid")
}

/// A randomized-but-valid architecture for property sweeps: crossbar
/// size, engine count, static split, replacement policy, reuse flag and
/// execution order all vary with the seed. Shared by the
/// parallel-determinism and artifact-IO suites so their coverage can
/// never silently diverge.
pub fn random_arch(rng: &mut SplitMix64) -> repro::accel::ArchConfig {
    use repro::accel::{ArchConfig, PolicyKind};
    use repro::pattern::tables::ExecOrder;
    let cfg = ArchConfig {
        crossbar_size: [2, 4, 8][rng.next_index(3)],
        total_engines: 4 + rng.next_bounded(28) as u32,
        policy: [
            PolicyKind::Lru,
            PolicyKind::RoundRobin,
            PolicyKind::Lfu,
            PolicyKind::Random,
        ][rng.next_index(4)],
        dynamic_reuse: rng.next_bool(0.5),
        order: if rng.next_bool(0.5) { ExecOrder::ColumnMajor } else { ExecOrder::RowMajor },
        ..ArchConfig::default()
    };
    ArchConfig {
        static_engines: rng.next_bounded(cfg.total_engines as u64) as u32,
        ..cfg
    }
}

/// Fresh scratch directory under the system temp root (the offline image
/// vendors no tempfile crate). Unique per process *and* call, so
/// parallel tests never share one; callers remove it when done (leaks
/// land in the OS temp dir, which is fine for CI).
pub fn scratch_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "repro-test-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every observable field of a [`repro::sched::RunResult`], compared bit
/// for bit — the determinism contract extended to loaded plans: one ULP
/// of timing or one event count off is a bug, not a tolerance question.
pub fn assert_bit_identical(
    got: &repro::sched::RunResult,
    want: &repro::sched::RunResult,
    ctx: &str,
) {
    assert_eq!(got.values, want.values, "{ctx}: values diverge");
    assert_eq!(got.counts, want.counts, "{ctx}: event counts diverge");
    assert_eq!(got.init_counts, want.init_counts, "{ctx}: init counts diverge");
    assert_eq!(got.exec_time_ns, want.exec_time_ns, "{ctx}: modeled time diverges");
    assert_eq!(got.init_time_ns, want.init_time_ns, "{ctx}: init time diverges");
    assert_eq!(got.supersteps, want.supersteps, "{ctx}: supersteps diverge");
    assert_eq!(got.iterations, want.iterations, "{ctx}: iterations diverge");
    assert_eq!(got.static_ops, want.static_ops, "{ctx}: static ops diverge");
    assert_eq!(got.dynamic_ops, want.dynamic_ops, "{ctx}: dynamic ops diverge");
    assert_eq!(got.dynamic_hits, want.dynamic_hits, "{ctx}: dynamic hits diverge");
    assert_eq!(
        got.static_hit_rate(),
        want.static_hit_rate(),
        "{ctx}: static hit rate diverges"
    );
    assert_eq!(
        got.max_dynamic_cell_writes, want.max_dynamic_cell_writes,
        "{ctx}: wear diverges"
    );
    assert_eq!(got.engines, want.engines, "{ctx}: per-engine summaries diverge");
}

/// The chunk-size axis of the parallel-preprocess property suite:
/// degenerate (1 edge per chunk), two awkward interior sizes, and the
/// whole edge list in one chunk. Every merged artifact must be
/// byte-identical across all of them — chunk boundaries are an
/// implementation detail that may never leak into any output.
pub fn chunk_sizes_for(g: &Coo) -> Vec<usize> {
    vec![1, 7, 64, g.edges.len().max(1)]
}

/// [`repro::pattern::partition_chunked`] at every chunk size in
/// [`chunk_sizes_for`], each asserted whole-struct-equal to the
/// monolithic [`repro::pattern::partition`] oracle.
pub fn assert_chunked_partition_matches(g: &Coo, c: usize, weighted: bool, ctx: &str) {
    let want = repro::pattern::partition(g, c, weighted);
    for chunk in chunk_sizes_for(g) {
        let got = repro::pattern::partition_chunked(g, c, weighted, chunk);
        assert_eq!(got, want, "{ctx}: chunk_edges={chunk} diverges from monolithic partition");
    }
}

/// The harness-default superstep lane count: `REPRO_THREADS` if set (the
/// CI matrix runs the whole suite at 1 and 4; `0` = auto, mapped through
/// the shared [`repro::sched::resolve_threads`] helper), else 2 so a
/// plain `cargo test` still exercises the parallel path. Tests that
/// sweep thread counts explicitly don't use this; tests that just need
/// "the configured parallelism" do.
pub fn default_threads() -> usize {
    repro::sched::resolve_threads(
        std::env::var("REPRO_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2),
    )
}

/// The harness-default shard count: `REPRO_SHARDS` if set (the CI matrix
/// adds a 2-shard leg), else 1 so a plain `cargo test` runs unsharded.
/// Like `REPRO_THREADS`, this is consumed only here — library code never
/// reads the environment. Tests that sweep shard counts explicitly don't
/// use this; tests that just need "the configured decomposition" do.
pub fn default_shards() -> u32 {
    std::env::var("REPRO_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&s| s >= 1)
        .unwrap_or(1)
}
