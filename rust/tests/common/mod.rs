//! Helpers shared by the integration-test binaries. Each binary uses its
//! own subset, so the module allows dead code as a whole.
#![allow(dead_code)]

use repro::algo::traits::INF;
use repro::graph::coo::{Coo, Edge};
use repro::graph::generator::{erdos_renyi, rmat, RmatParams};
use repro::util::SplitMix64;

/// Elementwise tolerance comparison treating any pair of values at or
/// above the INF sentinel as equal (unreached vertices).
pub fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if *g >= INF && *w >= INF {
            continue;
        }
        assert!((g - w).abs() <= tol, "{what}: vertex {i}: got {g}, want {w}");
    }
}

/// Seeded random graph for property sweeps: 32–512 vertices, R-MAT or
/// Erdős–Rényi, average degree 1–8. Every assertion over one should print
/// the seed (`"seed {seed}: ..."`) so failures are reproducible.
pub fn random_graph(seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    let n = 32 + rng.next_bounded(480) as u32;
    let m = (n as usize) * (1 + rng.next_index(8));
    if rng.next_bool(0.5) {
        rmat(n, m, RmatParams::default(), rng.next_u64())
    } else {
        erdos_renyi(n, m, rng.next_u64())
    }
}

/// Same topology with seeded random edge weights in [0.5, 4.5) — the
/// SSSP cases need real weight data.
pub fn with_random_weights(g: &Coo, rng: &mut SplitMix64) -> Coo {
    Coo::from_edges(
        g.num_vertices,
        g.edges
            .iter()
            .map(|e| Edge::weighted(e.src, e.dst, 0.5 + rng.next_f32() * 4.0))
            .collect(),
    )
}

/// The harness-default superstep lane count: `REPRO_THREADS` if set (the
/// CI matrix runs the whole suite at 1 and 4; `0` = auto, mapped through
/// the shared [`repro::sched::resolve_threads`] helper), else 2 so a
/// plain `cargo test` still exercises the parallel path. Tests that
/// sweep thread counts explicitly don't use this; tests that just need
/// "the configured parallelism" do.
pub fn default_threads() -> usize {
    repro::sched::resolve_threads(
        std::env::var("REPRO_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2),
    )
}
