//! Integration + property tests over the serving coordinator: queueing
//! invariants, metrics conservation, shared-session cache behaviour under
//! concurrency, backend honoring, and determinism of served results.

use std::path::PathBuf;
use std::sync::Arc;

use repro::accel::ArchConfig;
use repro::algo::reference;
use repro::coordinator::{Service, ServiceConfig};
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::graph::Csr;
use repro::session::{Backend, JobSpec, Session};
use repro::util::SplitMix64;

mod common;
use common::{assert_close, default_threads, scratch_dir};

fn service(workers: usize) -> Service {
    Service::spawn(ServiceConfig {
        arch: ArchConfig::default(),
        params: CostParams::default(),
        backend: Backend::Native,
        workers,
        // The harness default (REPRO_THREADS): the whole coordinator
        // suite runs against both the sequential and the parallel
        // scheduler in CI, and every assertion must hold unchanged.
        parallelism: default_threads(),
        preprocess_parallelism: None,
        artifact_dir: None,
        queue_depth: repro::coordinator::DEFAULT_QUEUE_DEPTH,
    })
    .unwrap()
}

#[test]
fn metrics_conserve_jobs() {
    // Property: submitted == completed + failed after all jobs resolve,
    // across random job mixes and worker counts.
    let algos = ["bfs", "pagerank", "wcc", "sssp"];
    for seed in 0..6u64 {
        let mut rng = SplitMix64::new(seed);
        let workers = 1 + rng.next_index(4);
        let svc = service(workers);
        let njobs = 4 + rng.next_index(12);
        let pending: Vec<_> = (0..njobs)
            .map(|i| {
                let spec = JobSpec::new(Dataset::Tiny, algos[rng.next_index(4)])
                    .with_source(i as u32)
                    .with_iterations(3);
                svc.submit(spec).unwrap()
            })
            .collect();
        let mut ok = 0u64;
        for p in pending {
            if p.wait().is_ok() {
                ok += 1;
            }
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.jobs_submitted, njobs as u64, "seed {seed}");
        assert_eq!(snap.jobs_completed, ok, "seed {seed}");
        assert_eq!(snap.jobs_completed + snap.jobs_failed, njobs as u64, "seed {seed}");
        assert!(snap.max_latency_us >= snap.mean_latency_us as u64, "seed {seed}");
        // Per-algorithm counters sum to the global ones and nothing is
        // left in flight after every job resolved.
        let per: u64 = snap.per_algorithm.values().map(|s| s.completed + s.failed).sum();
        assert_eq!(per, njobs as u64, "seed {seed}");
        assert!(
            snap.per_algorithm.values().all(|s| s.queue_depth == 0),
            "seed {seed}: {:?}",
            snap.per_algorithm
        );
    }
}

#[test]
fn served_results_are_deterministic() {
    // The same job must produce identical reports regardless of worker
    // interleaving or cache state.
    let svc = service(4);
    let job = || JobSpec::new(Dataset::Tiny, "bfs").with_source(7);
    let first = svc.submit_blocking(job()).unwrap().report;
    let pending: Vec<_> = (0..6).map(|_| svc.submit(job()).unwrap()).collect();
    for p in pending {
        let r = p.wait().unwrap().report;
        assert_eq!(
            r.run.as_ref().unwrap().values,
            first.run.as_ref().unwrap().values
        );
        assert_eq!(r.counts, first.counts);
        assert_eq!(r.exec_time_ns, first.exec_time_ns);
    }
}

#[test]
fn parallel_service_serves_bit_identical_reports() {
    // Workers honoring the session's parallelism must change nothing
    // observable: a REPRO_THREADS-parallel service and an explicitly
    // sequential one return bit-identical reports for a mixed batch.
    let seq = Service::spawn(ServiceConfig { parallelism: 1, ..ServiceConfig::default() })
        .unwrap();
    // .max(2): under the REPRO_THREADS=1 CI leg this comparison must not
    // degenerate to sequential-vs-sequential.
    let par = Service::spawn(ServiceConfig {
        parallelism: default_threads().max(2),
        ..ServiceConfig::default()
    })
    .unwrap();
    let batch = || {
        vec![
            JobSpec::new(Dataset::Tiny, "bfs").with_source(2),
            JobSpec::new(Dataset::Tiny, "sssp").with_source(0),
            JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(5),
            JobSpec::new(Dataset::Tiny, "wcc"),
        ]
    };
    let a: Vec<_> = seq
        .submit_batch(batch())
        .unwrap()
        .into_iter()
        .map(|p| p.wait().unwrap())
        .collect();
    let b: Vec<_> = par
        .submit_batch(batch())
        .unwrap()
        .into_iter()
        .map(|p| p.wait().unwrap())
        .collect();
    for (x, y) in a.iter().zip(&b) {
        let algo = &x.report.algorithm;
        assert_eq!(
            x.report.run.as_ref().unwrap().values,
            y.report.run.as_ref().unwrap().values,
            "{algo}: values"
        );
        assert_eq!(x.report.counts, y.report.counts, "{algo}: counts");
        assert_eq!(x.report.exec_time_ns, y.report.exec_time_ns, "{algo}: time");
        assert_eq!(
            x.report.static_hit_rate, y.report.static_hit_rate,
            "{algo}: hit rate"
        );
    }
}

#[test]
fn preprocessing_cache_accelerates_repeat_jobs() {
    let svc = service(1);
    // Cold: includes dataset generation + Alg. 1.
    let cold = svc
        .submit_blocking(JobSpec::new(Dataset::Gnutella, "bfs"))
        .unwrap()
        .wall_time_us;
    // Warm average.
    let mut warm_total = 0u64;
    for i in 1..4u32 {
        warm_total += svc
            .submit_blocking(JobSpec::new(Dataset::Gnutella, "bfs").with_source(i))
            .unwrap()
            .wall_time_us;
    }
    let warm = warm_total / 3;
    assert!(
        warm < cold,
        "warm jobs ({warm} µs) not faster than cold ({cold} µs)"
    );
}

#[test]
fn scale_variants_do_not_collide_in_cache() {
    let svc = service(2);
    let a = svc
        .submit_blocking(JobSpec::new(Dataset::Tiny, "bfs"))
        .unwrap();
    let b = svc
        .submit_blocking(JobSpec::new(Dataset::Tiny, "bfs").with_scale(0.5))
        .unwrap();
    assert_ne!(
        a.report.run.as_ref().unwrap().values.len(),
        b.report.run.as_ref().unwrap().values.len(),
        "different scales must map to different preprocessed graphs"
    );
}

#[test]
fn mixed_submit_batch_is_correct_and_preprocesses_once_per_dataset() {
    // The acceptance test for the Session facade: a 4-algorithm mixed
    // batch through 4 workers returns reference-correct results while
    // Alg. 1 runs once per (dataset, weighted) artifact key.
    let session = Arc::new(Session::builder().build().unwrap());
    let svc = Service::with_session(Arc::clone(&session), 4);
    let d = Dataset::Tiny;
    let batch = vec![
        JobSpec::new(d, "bfs").with_source(0),
        JobSpec::new(d, "sssp").with_source(0),
        JobSpec::new(d, "pagerank").with_iterations(8),
        JobSpec::new(d, "wcc"),
        // Second wave of the same mix → pure cache hits.
        JobSpec::new(d, "bfs").with_source(3),
        JobSpec::new(d, "wcc"),
    ];
    let n = batch.len() as u64;
    let results: Vec<_> = svc
        .submit_batch(batch)
        .unwrap()
        .into_iter()
        .map(|p| p.wait().unwrap())
        .collect();

    let csr = Csr::from_coo(&d.load().unwrap());
    let wcsr = Csr::from_coo(&d.load_weighted(1.0).unwrap());
    fn values(r: &repro::coordinator::JobResult) -> &[f32] {
        &r.report.run.as_ref().unwrap().values
    }
    assert_close(values(&results[0]), &reference::bfs_levels(&csr, 0), 1e-3, "bfs");
    assert_close(values(&results[1]), &reference::sssp_distances(&wcsr, 0), 1e-2, "sssp");
    assert_close(values(&results[2]), &reference::pagerank(&csr, 0.85, 8), 1e-4, "pagerank");
    assert_close(values(&results[3]), &reference::wcc_labels(&csr), 0.0, "wcc");
    assert_close(values(&results[4]), &reference::bfs_levels(&csr, 3), 1e-3, "bfs from 3");

    // One unweighted + one weighted artifact — exactly two Alg.-1 runs
    // across all workers; everything else hit the shared store.
    let cache = session.artifacts().stats();
    assert_eq!(cache.misses, 2, "preprocessing must run once per dataset key");
    assert_eq!(cache.hits, n - 2);

    let snap = svc.metrics.snapshot();
    assert_eq!(snap.jobs_completed, n);
    assert_eq!(snap.per_algorithm["bfs"].completed, 2);
    assert_eq!(snap.per_algorithm["wcc"].completed, 2);
    assert_eq!(snap.per_algorithm["sssp"].completed, 1);
    assert_eq!(snap.per_algorithm["pagerank"].completed, 1);
    assert!(snap.per_algorithm.values().all(|s| s.queue_depth == 0));
}

#[test]
fn serve_jobs_share_one_compiled_execution_plan() {
    // Mirror of the preprocess-once assertion for the PR-2 plan layer:
    // repeated serve jobs with the same (dataset, scale, weighted, arch)
    // key must interpret the *same compiled ExecutionPlan instance*, not
    // rebuild the schedule per job or per worker.
    let session = Arc::new(Session::builder().build().unwrap());
    let svc = Service::with_session(Arc::clone(&session), 4);
    let pending = svc
        .submit_batch((0..8u32).map(|i| JobSpec::new(Dataset::Tiny, "bfs").with_source(i)))
        .unwrap();
    for p in pending {
        p.wait().unwrap();
    }
    // Exactly one Alg.-1 run — and the plan is compiled inside it.
    assert_eq!(
        session.artifacts().stats().misses,
        1,
        "plan must be compiled exactly once across all workers"
    );
    // The store serves the same Arc'd artifact (hence the same plan
    // allocation) to every subsequent caller of the key.
    let spec = JobSpec::new(Dataset::Tiny, "bfs");
    let a = session.preprocess(&spec).unwrap();
    let b = session.preprocess(&spec).unwrap();
    // Same Arc'd artifact ⇒ same compiled plan allocation inside it.
    assert!(Arc::ptr_eq(&a, &b), "artifact (and plan) instance must be shared");
    assert!(a.plan.num_ops() > 0);
    assert_eq!(a.plan.num_ops(), a.st.len(), "one plan op per ST entry");
}

#[test]
fn serve_warm_start_performs_zero_plan_compilations() {
    // The tentpole acceptance at the serving layer: a "redeployed" fleet
    // (a second Service over the same --artifact-dir) deserializes its
    // compiled plans instead of re-running Alg. 1, and serves reports
    // bit-identical to the cold fleet's.
    let dir = scratch_dir("serve-warm");
    let batch = || {
        vec![
            JobSpec::new(Dataset::Tiny, "bfs").with_source(2),
            JobSpec::new(Dataset::Tiny, "sssp").with_source(0),
            JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(5),
            JobSpec::new(Dataset::Tiny, "wcc"),
        ]
    };
    let config = || ServiceConfig {
        workers: 4,
        parallelism: default_threads(),
        artifact_dir: Some(dir.clone()),
        ..ServiceConfig::default()
    };

    let cold = Service::spawn(config()).unwrap();
    let a: Vec<_> = cold
        .submit_batch(batch())
        .unwrap()
        .into_iter()
        .map(|p| p.wait().unwrap())
        .collect();
    let s = cold.session().artifacts().stats();
    assert_eq!(s.misses, 2, "cold fleet compiles once per (weighted) key");
    assert_eq!(s.writes, 2, "cold fleet persists both artifacts");
    drop(cold);

    let warm = Service::spawn(config()).unwrap();
    let b: Vec<_> = warm
        .submit_batch(batch())
        .unwrap()
        .into_iter()
        .map(|p| p.wait().unwrap())
        .collect();
    let s = warm.session().artifacts().stats();
    assert_eq!(s.misses, 0, "warm fleet must perform zero plan compilations");
    assert_eq!(s.disk_hits, 2, "warm fleet loads both artifacts from disk");
    for (x, y) in a.iter().zip(&b) {
        let algo = &x.report.algorithm;
        assert_eq!(
            x.report.run.as_ref().unwrap().values,
            y.report.run.as_ref().unwrap().values,
            "{algo}: warm values diverge"
        );
        assert_eq!(x.report.counts, y.report.counts, "{algo}: warm counts diverge");
        assert_eq!(x.report.exec_time_ns, y.report.exec_time_ns, "{algo}: warm time diverges");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn artifact_store_clear_removes_disk_entries_and_tracks_disk_stats() {
    // ArtifactStats disk counters + the documented clear() contract:
    // clearing a two-tier store empties the directory too, so the next
    // session recomputes instead of resurrecting a cleared artifact.
    let dir = scratch_dir("clear-disk");
    let store = Arc::new(repro::session::ArtifactStore::with_dir(&dir).unwrap());
    let session = Arc::new(
        Session::builder().artifacts(Arc::clone(&store)).build().unwrap(),
    );
    let svc = Service::with_session(Arc::clone(&session), 2);
    let pending = svc
        .submit_batch((0..4u32).map(|i| JobSpec::new(Dataset::Tiny, "bfs").with_source(i)))
        .unwrap();
    for p in pending {
        p.wait().unwrap();
    }
    let s = store.stats();
    assert_eq!((s.misses, s.disk_misses, s.writes), (1, 1, 1));
    assert_eq!(s.hits, 3);
    assert_eq!(
        repro::session::DiskStore::open(&dir).unwrap().entries().len(),
        1,
        "the artifact file must exist before clear()"
    );

    store.clear();
    assert!(
        repro::session::DiskStore::open(&dir).unwrap().entries().is_empty(),
        "clear() must remove on-disk entries"
    );
    assert_eq!(store.stats().entries, 0);

    // Post-clear: a fresh request is a full recompute (and re-persists).
    svc.submit_blocking(JobSpec::new(Dataset::Tiny, "bfs")).unwrap();
    let s = store.stats();
    assert_eq!(s.misses, 2, "cleared artifact must be recompiled");
    assert_eq!(s.writes, 2, "and persisted again");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pjrt_service_fails_loudly_when_artifacts_missing() {
    // A PJRT-configured service must refuse to spawn (never silently
    // fall back to the native executor) when artifacts are absent.
    let cfg = ServiceConfig {
        backend: Backend::Pjrt(PathBuf::from("/definitely/not/an/artifact/dir")),
        ..ServiceConfig::default()
    };
    let err = Service::spawn(cfg).err().expect("spawn must fail, not fall back");
    let msg = format!("{err:#}");
    assert!(msg.contains("pjrt"), "error must name the backend: {msg}");
}

#[test]
fn heavy_concurrency_smoke() {
    let svc = service(8);
    let pending = svc
        .submit_batch((0..64).map(|_| JobSpec::new(Dataset::Tiny, "wcc")))
        .unwrap();
    for p in pending {
        p.wait().unwrap();
    }
    assert_eq!(svc.metrics.snapshot().jobs_completed, 64);
    assert_eq!(svc.session().artifacts().stats().misses, 1);
}
