//! Integration + property tests over the serving coordinator: queueing
//! invariants, metrics conservation, cache behaviour under concurrency,
//! and determinism of served results.

use repro::accel::ArchConfig;
use repro::coordinator::{Job, Service, ServiceConfig};
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::util::SplitMix64;

fn service(workers: usize) -> Service {
    Service::spawn(ServiceConfig {
        arch: ArchConfig::default(),
        params: CostParams::default(),
        workers,
    })
}

#[test]
fn metrics_conserve_jobs() {
    // Property: submitted == completed + failed after all jobs resolve,
    // across random job mixes and worker counts.
    for seed in 0..6u64 {
        let mut rng = SplitMix64::new(seed);
        let workers = 1 + rng.next_index(4);
        let svc = service(workers);
        let njobs = 4 + rng.next_index(12);
        let pending: Vec<_> = (0..njobs)
            .map(|i| {
                let job = match rng.next_index(4) {
                    0 => Job::Bfs { dataset: Dataset::Tiny, scale: 1.0, source: i as u32 },
                    1 => Job::PageRank { dataset: Dataset::Tiny, scale: 1.0, iterations: 3 },
                    2 => Job::Wcc { dataset: Dataset::Tiny, scale: 1.0 },
                    _ => Job::Sssp { dataset: Dataset::Tiny, scale: 1.0, source: i as u32 },
                };
                svc.submit(job).unwrap()
            })
            .collect();
        let mut ok = 0u64;
        for p in pending {
            if p.wait().is_ok() {
                ok += 1;
            }
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.jobs_submitted, njobs as u64, "seed {seed}");
        assert_eq!(snap.jobs_completed, ok, "seed {seed}");
        assert_eq!(snap.jobs_completed + snap.jobs_failed, njobs as u64, "seed {seed}");
        assert!(snap.max_latency_us >= snap.mean_latency_us as u64, "seed {seed}");
    }
}

#[test]
fn served_results_are_deterministic() {
    // The same job must produce identical reports regardless of worker
    // interleaving or cache state.
    let svc = service(4);
    let job = || Job::Bfs { dataset: Dataset::Tiny, scale: 1.0, source: 7 };
    let first = svc.submit_blocking(job()).unwrap().report;
    let pending: Vec<_> = (0..6).map(|_| svc.submit(job()).unwrap()).collect();
    for p in pending {
        let r = p.wait().unwrap().report;
        assert_eq!(
            r.run.as_ref().unwrap().values,
            first.run.as_ref().unwrap().values
        );
        assert_eq!(r.counts, first.counts);
        assert_eq!(r.exec_time_ns, first.exec_time_ns);
    }
}

#[test]
fn preprocessing_cache_accelerates_repeat_jobs() {
    let svc = service(1);
    // Cold: includes dataset generation + Alg. 1.
    let cold = svc
        .submit_blocking(Job::Bfs { dataset: Dataset::Gnutella, scale: 1.0, source: 0 })
        .unwrap()
        .wall_time_us;
    // Warm average.
    let mut warm_total = 0u64;
    for i in 1..4u32 {
        warm_total += svc
            .submit_blocking(Job::Bfs { dataset: Dataset::Gnutella, scale: 1.0, source: i })
            .unwrap()
            .wall_time_us;
    }
    let warm = warm_total / 3;
    assert!(
        warm < cold,
        "warm jobs ({warm} µs) not faster than cold ({cold} µs)"
    );
}

#[test]
fn scale_variants_do_not_collide_in_cache() {
    let svc = service(2);
    let a = svc
        .submit_blocking(Job::Bfs { dataset: Dataset::Tiny, scale: 1.0, source: 0 })
        .unwrap();
    let b = svc
        .submit_blocking(Job::Bfs { dataset: Dataset::Tiny, scale: 0.5, source: 0 })
        .unwrap();
    assert_ne!(
        a.report.run.as_ref().unwrap().values.len(),
        b.report.run.as_ref().unwrap().values.len(),
        "different scales must map to different preprocessed graphs"
    );
}

#[test]
fn heavy_concurrency_smoke() {
    let svc = service(8);
    let pending: Vec<_> = (0..64u32)
        .map(|i| {
            svc.submit(Job::Wcc { dataset: Dataset::Tiny, scale: 1.0 })
                .map(|p| (i, p))
                .unwrap()
        })
        .collect();
    for (_, p) in pending {
        p.wait().unwrap();
    }
    assert_eq!(svc.metrics.snapshot().jobs_completed, 64);
}
