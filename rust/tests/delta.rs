//! Streaming-mutation property suite — the lockdown for the edge-delta
//! ingest path (`graph::delta` → `sched::patch` → `Session::apply_delta`).
//!
//! The central contract is **bit-identity**: a patched artifact must
//! compare equal (`PartialEq`, every field) to a cold
//! `Accelerator::preprocess` of the mutated graph, and every execution
//! mechanism — sequential interpreter, scoped spawns, persistent worker
//! pool, threads 1–8 — must produce bit-identical `RunResult`s from the
//! patched plan. Random graphs × random architectures × random delta
//! batches × all four algorithms; every assertion carries its seed.
//!
//! The disk legs extend the contract across processes: a patched
//! artifact republished to a shared directory warm-serves (zero
//! compilations) into a fresh store/session, carrying its accumulated
//! [`DeltaProvenance`](repro::session::DeltaProvenance) stamp.

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::traits::VertexProgram;
use repro::algo::{Bfs, PageRank, Sssp, Wcc};
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::graph::{DeltaBatch, EdgeDelta};
use repro::sched::executor::NativeExecutor;
use repro::sched::{
    patch_preprocessed, run_parallel_pooled, run_parallel_scoped, PatchStats, WorkerPool,
};
use repro::session::{ArtifactKey, ArtifactStore, DiskStore, JobSpec, Session};
use repro::util::SplitMix64;

mod common;
use common::{
    assert_bit_identical, default_threads, random_arch, random_delta_batch, random_graph,
    scratch_dir, with_random_weights,
};

/// One-delta batch against an `n`-vertex graph.
fn single(n: u32, delta: EdgeDelta) -> DeltaBatch {
    DeltaBatch::new(n, vec![delta]).unwrap()
}

#[test]
fn prop_patched_artifact_equals_cold_recompile() {
    for seed in 600..608u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0xDE17A);
        let arch = random_arch(&mut rng);
        let gw = with_random_weights(&g, &mut rng);
        for (graph, weighted) in [(&g, false), (&gw, true)] {
            let batch = random_delta_batch(graph, &mut rng);
            let acc = Accelerator::new(arch.clone(), CostParams::default());
            let mut patched = acc.preprocess(graph, weighted).unwrap();
            let stats = patch_preprocessed(&mut patched, &batch, &acc.config).unwrap();
            let cold = acc.preprocess(&batch.apply_to_coo(graph).unwrap(), weighted).unwrap();
            assert_eq!(
                patched, cold,
                "seed {seed} weighted {weighted} arch {arch:?}: patched != cold recompile"
            );
            // Every delta in the canonical batch was applied exactly once.
            assert_eq!(
                (stats.adds + stats.removes + stats.reweights) as usize,
                batch.len(),
                "seed {seed} weighted {weighted}: op accounting"
            );
        }
    }
}

#[test]
fn prop_patched_plan_runs_bit_identical_for_every_algorithm_and_mechanism() {
    for seed in 620..624u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0x0D17);
        let source = rng.next_bounded(g.num_vertices as u64) as u32;
        let arch = random_arch(&mut rng);
        let gw = with_random_weights(&g, &mut rng);
        let bfs = Bfs::new(source);
        let sssp = Sssp::new(source);
        let pagerank = PageRank::new(0.85, 4);
        let wcc = Wcc;
        let programs: [(&dyn VertexProgram, bool); 4] =
            [(&bfs, false), (&sssp, true), (&pagerank, false), (&wcc, false)];
        let acc = Accelerator::new(arch.clone(), CostParams::default());
        let params = CostParams::default();
        for (program, weighted) in programs {
            let graph = if weighted { &gw } else { &g };
            let batch = random_delta_batch(graph, &mut rng);
            let mut patched = acc.preprocess(graph, weighted).unwrap();
            patch_preprocessed(&mut patched, &batch, &acc.config).unwrap();
            let cold = acc.preprocess(&batch.apply_to_coo(graph).unwrap(), weighted).unwrap();
            let ctx = format!("seed {seed} algo {} arch {arch:?}", program.name());

            let want = acc
                .run_threaded(&cold, program, &mut NativeExecutor, 1)
                .unwrap()
                .run
                .unwrap();
            let got_seq = acc
                .run_threaded(&patched, program, &mut NativeExecutor, 1)
                .unwrap()
                .run
                .unwrap();
            assert_bit_identical(&got_seq, &want, &format!("{ctx} [sequential]"));
            for threads in [2usize, 4, 8] {
                let got_scoped = run_parallel_scoped(
                    &arch,
                    &params,
                    &patched.plan,
                    program,
                    &mut NativeExecutor,
                    threads,
                )
                .unwrap();
                assert_bit_identical(&got_scoped, &want, &format!("{ctx} [scoped x{threads}]"));
                let mut pool = WorkerPool::new(threads);
                let got_pooled = run_parallel_pooled(
                    &arch,
                    &params,
                    &patched.plan,
                    program,
                    &mut NativeExecutor,
                    &mut pool,
                )
                .unwrap();
                assert_bit_identical(&got_pooled, &want, &format!("{ctx} [pooled x{threads}]"));
            }
        }
    }
}

#[test]
fn empty_batch_is_identity_through_the_session() {
    let session = Session::with_defaults().unwrap();
    let spec = JobSpec::new(Dataset::Tiny, "bfs").with_source(0);
    let before = session.run(&spec).unwrap();
    let n = session.load_graph(&spec).unwrap().num_vertices;
    let report = session.apply_delta(&spec, &DeltaBatch::empty(n)).unwrap();
    assert_eq!(report.deltas, 0);
    assert_eq!(report.stats, PatchStats::default());
    let after = session.run(&spec).unwrap();
    assert_bit_identical(after.run.as_ref().unwrap(), before.run.as_ref().unwrap(), "empty batch");
    assert_eq!(before.counts, after.counts);
    assert_eq!(before.exec_time_ns, after.exec_time_ns);
}

#[test]
fn remove_then_re_add_restores_the_artifact_bit_for_bit() {
    for (weighted, seed) in [(false, 700u64), (true, 701)] {
        let g0 = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0xAB);
        let g = if weighted {
            with_random_weights(&g0, &mut rng)
        } else {
            g0
        };
        let acc = Accelerator::with_defaults();
        let mut pre = acc.preprocess(&g, weighted).unwrap();
        let original = pre.clone();
        let e = g.edges[rng.next_index(g.edges.len())];
        let remove = single(g.num_vertices, EdgeDelta::remove(e.src, e.dst));
        patch_preprocessed(&mut pre, &remove, &acc.config).unwrap();
        assert_ne!(pre.part, original.part, "seed {seed}: removal must change the partitioning");
        // Two sequential batches, not one: in a single batch the pair
        // would dedup last-wins into a bare add of an existing edge.
        let readd = single(g.num_vertices, EdgeDelta::add_weighted(e.src, e.dst, e.weight));
        patch_preprocessed(&mut pre, &readd, &acc.config).unwrap();
        assert_eq!(
            pre, original,
            "seed {seed} weighted {weighted}: remove + re-add must restore the artifact"
        );
    }
}

#[test]
fn patched_artifact_warm_serves_across_stores_with_provenance() {
    let dir = scratch_dir("delta-warm");
    let arch = ArchConfig::default();
    let acc = Accelerator::with_defaults();
    let key = ArtifactKey::new(Dataset::Tiny, 1.0, false, &arch);
    let g = Dataset::Tiny.load().unwrap();
    let e = g.edges[0];
    let batch = single(g.num_vertices, EdgeDelta::remove(e.src, e.dst));

    let first = ArtifactStore::with_dir(&dir).unwrap();
    first.get_or_preprocess(key, &acc).unwrap();
    let stats = first.patch(key, &arch, &batch).unwrap().expect("cached key patches");
    assert_eq!(stats.removes, 1);
    let patched = first.get(&key).unwrap();

    // A fresh store over the same directory serves the *patched*
    // artifact warm — zero compilations — and it equals both the
    // in-memory patched copy and a cold recompile of the mutated graph.
    let second = ArtifactStore::with_dir(&dir).unwrap();
    let served = second.get_or_preprocess(key, &acc).unwrap();
    let s = second.stats();
    assert_eq!((s.misses, s.disk_hits), (0, 1), "patched artifact must warm-serve");
    assert_eq!(*served, *patched);
    let cold = acc.preprocess(&batch.apply_to_coo(&g).unwrap(), false).unwrap();
    assert_eq!(*served, cold);

    // The provenance stamp survived the disk round trip.
    let (_, prov, _) = DiskStore::open(&dir).unwrap().load_with(&key, &arch).unwrap();
    assert_eq!(prov.batches, 1);
    assert_eq!(prov.dirty_partitions, u64::from(stats.dirty_partitions));
    assert_eq!(prov.patched_ops, u64::from(stats.patched_ops));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mutated_session_warm_restarts_from_patched_disk_artifacts() {
    let dir = scratch_dir("delta-session");
    let spec = JobSpec::new(Dataset::Tiny, "sssp")
        .with_source(0)
        .with_parallelism(default_threads());
    let first = Session::builder().artifact_dir(&dir).build().unwrap();
    first.run(&spec).unwrap();

    let g = first.load_graph(&spec).unwrap();
    let e = g.edges[0];
    let batch = single(g.num_vertices, EdgeDelta::reweight(e.src, e.dst, 9.5));
    let report = first.apply_delta(&spec, &batch).unwrap();
    // sssp caches only the weighted key; the unweighted one is skipped.
    assert_eq!((report.patched_artifacts, report.skipped_keys), (1, 1));
    let want = first.run(&spec).unwrap();
    drop(first);

    // A restarted process: fresh session, empty delta log, warm
    // directory — the patched plan is served with zero compilations and
    // runs bit-identical to the pre-restart mutated result.
    let second = Session::builder().artifact_dir(&dir).build().unwrap();
    let got = second.run(&spec).unwrap();
    let s = second.artifacts().stats();
    assert_eq!((s.misses, s.disk_hits), (0, 1), "restart must warm-serve the patched plan");
    assert_bit_identical(got.run.as_ref().unwrap(), want.run.as_ref().unwrap(), "restart");
    assert_eq!(got.counts, want.counts);
    assert_eq!(got.exec_time_ns, want.exec_time_ns);
    let _ = std::fs::remove_dir_all(&dir);
}
