//! Integration: the paper's *qualitative* results must hold on the
//! synthetic datasets — who wins, by roughly what factor, where the
//! crossover falls (DESIGN.md §4 acceptance bar). Full-scale numbers are
//! produced by the benches; these tests run at reduced scale to stay
//! fast, asserting orderings and coarse ratios rather than absolutes.

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::Bfs;
use repro::baselines::{self, BaselineModel, GraphR, SparseMem, TaRe};
use repro::cost::{lifetime_seconds, CostParams};
use repro::dse::static_engine_sweep;
use repro::graph::datasets::Dataset;
use repro::sched::executor::NativeExecutor;

fn ours(g: &repro::graph::Coo) -> repro::accel::SimReport {
    Accelerator::with_defaults()
        .simulate(g, &Bfs::new(0), &mut NativeExecutor)
        .unwrap()
}

/// Table 4 shape: energy ordering GraphR ≫ SparseMEM > TARe > Proposed,
/// with Proposed beating SparseMEM by >2x and GraphR by >2 orders.
#[test]
fn table4_energy_ordering() {
    for d in [Dataset::WikiVote, Dataset::Gnutella] {
        let g = d.load().unwrap();
        let params = CostParams::default();
        let us = ours(&g).energy_j();
        let gr = GraphR::default().simulate_bfs(&g, 0, &params, 32).energy_j();
        let sm = SparseMem::default().simulate_bfs(&g, 0, &params, 32).energy_j();
        let ta = TaRe::default().simulate_bfs(&g, 0, &params, 32).energy_j();
        let short = d.spec().short;
        // Paper reports 2–4 orders vs GraphR; our kinder GraphR model
        // still leaves a >20x gap on the small graphs and orders of
        // magnitude on the large ones (see benches for the full table).
        assert!(gr > 20.0 * us, "{short}: GraphR {gr:.2e} vs ours {us:.2e}");
        assert!(sm > 1.5 * us, "{short}: SparseMEM {sm:.2e} vs ours {us:.2e}");
        assert!(ta > us, "{short}: TARe {ta:.2e} vs ours {us:.2e}");
        assert!(gr > sm && gr > ta, "{short}: GraphR must be worst");
    }
}

/// Fig. 7 shape: speedup ordering Proposed > TARe > SparseMEM ≫ GraphR.
#[test]
fn fig7_speedup_ordering() {
    for d in [Dataset::WikiVote, Dataset::Gnutella] {
        let g = d.load().unwrap();
        let params = CostParams::default();
        let us = ours(&g).exec_time_ns;
        let gr = GraphR::default().simulate_bfs(&g, 0, &params, 32).exec_time_ns;
        let sm = SparseMem::default().simulate_bfs(&g, 0, &params, 32).exec_time_ns;
        let ta = TaRe::default().simulate_bfs(&g, 0, &params, 32).exec_time_ns;
        let short = d.spec().short;
        assert!(gr > 100.0 * us, "{short}: vs GraphR only {:.1}x", gr / us);
        assert!(sm > us, "{short}: SparseMEM faster than us");
        assert!(ta > us, "{short}: TARe faster than us");
        // Paper: ours/TARe ≈ 1.27x, ours/SparseMEM ≈ 2.38x — both are
        // single-digit factors, not orders of magnitude.
        assert!(ta / us < 20.0, "{short}: TARe gap implausibly large");
        assert!(sm / us < 20.0, "{short}: SparseMEM gap implausibly large");
    }
}

/// Fig. 6 shape: some intermediate static split beats both extremes, and
/// the all-static-but-one end loses to the optimum.
#[test]
fn fig6_hump_exists() {
    let g = Dataset::WikiVote.load_scaled(0.4).unwrap();
    let points = static_engine_sweep(
        &g,
        &ArchConfig::default(),
        &CostParams::default(),
        &Bfs::new(0),
        &[0, 8, 16, 24, 31],
    )
    .unwrap();
    let speed = |n: u32| points.iter().find(|p| p.x == n).unwrap().speedup;
    let best = points.iter().map(|p| p.speedup).fold(0.0, f64::max);
    assert!(best > 1.2, "no meaningful speedup from static engines: {best:.2}");
    // The optimum is an interior point (paper: N = 16).
    assert!(best > speed(0) && best > speed(31), "optimum at an extreme");
    let best_n = points
        .iter()
        .max_by(|a, b| a.speedup.total_cmp(&b.speedup))
        .unwrap()
        .x;
    assert!(
        (8..=24).contains(&best_n),
        "optimum N={best_n} outside the paper's interior region"
    );
}

/// §IV.D shape: lifetime ordering Proposed > SparseMEM ≫ GraphR, with
/// the proposed design exceeding 10 years at hourly executions.
#[test]
fn lifetime_ordering() {
    let g = Dataset::WikiVote.load().unwrap();
    let params = CostParams::default();
    let engines = 128;
    let cfg = ArchConfig::lifetime();
    let acc = Accelerator::new(cfg, params.clone());
    let us = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor).unwrap();
    let base = baselines::simulate_all(&g, 0, &params, engines);
    let w = |name: &str| {
        base.iter()
            .find(|r| r.design == name)
            .unwrap()
            .max_cell_writes
    };
    let lt = |w: u64| lifetime_seconds(params.endurance_cycles, w, 3600.0);
    let ours_lt = lt(us.max_cell_writes);
    let ten_years = 10.0 * 365.25 * 24.0 * 3600.0;
    assert!(ours_lt > ten_years, "proposed lifetime {ours_lt:.2e} s < 10 years");
    assert!(ours_lt > lt(w("SparseMEM")), "must outlive SparseMEM");
    assert!(lt(w("SparseMEM")) > lt(w("GraphR")), "SparseMEM must outlive GraphR");
    assert!(
        ours_lt > 10.0 * lt(w("GraphR")),
        "vs GraphR only {:.1}x",
        ours_lt / lt(w("GraphR"))
    );
    // TARe is write-free: infinite lifetime by construction.
    assert!(lt(w("TARe")).is_infinite());
}

/// Fig. 1a shape: pattern histogram skew on every dataset — the top-16
/// patterns must cover the majority of subgraphs (paper: 86 % on WV).
#[test]
fn fig1_skew_on_all_datasets() {
    for d in [Dataset::WikiVote, Dataset::Gnutella, Dataset::Epinions] {
        let g = d.load_scaled(if d == Dataset::Epinions { 0.3 } else { 1.0 }).unwrap();
        let acc = Accelerator::with_defaults();
        let pre = acc.preprocess(&g, false).unwrap();
        let cov = pre.ranking.coverage(16);
        assert!(cov > 0.55, "{}: top-16 coverage {cov:.3}", d.spec().short);
    }
}

/// Fig. 5 shape: static engines see far more read traffic than dynamic
/// ones; dynamic engines own all the writes.
#[test]
fn fig5_static_dynamic_asymmetry() {
    let g = Dataset::WikiVote.load_scaled(0.4).unwrap();
    let acc = Accelerator::new(ArchConfig::fig5(), CostParams::default());
    let r = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor).unwrap();
    let run = r.run.as_ref().unwrap();
    let trace = run.activity.as_ref().unwrap();
    let totals = trace.totals();
    let static_reads: u64 = totals[..4].iter().map(|t| t.0).sum();
    let dynamic_reads: u64 = totals[4..].iter().map(|t| t.0).sum();
    let static_writes: u64 = totals[..4].iter().map(|t| t.1).sum();
    let dynamic_writes: u64 = totals[4..].iter().map(|t| t.1).sum();
    // Static engines serve ~80 % of ops; the row-address shortcut trims
    // their per-op reads, so assert a clear majority rather than the
    // paper's unquantified "significantly higher".
    assert!(
        static_reads as f64 > 1.4 * dynamic_reads as f64,
        "static reads {static_reads} vs dynamic {dynamic_reads}"
    );
    assert_eq!(static_writes, 0, "static engines wrote at runtime");
    assert!(dynamic_writes > 0);
}
