//! Differential parallel-determinism suite — the lockdown for the
//! batch-parallel scheduler (`sched::par`).
//!
//! For random graphs × all four algorithms × randomized architectures,
//! the full [`RunResult`] (values, `EventCounts`, `init_counts`, timing,
//! `static_hit_rate`, `max_dynamic_cell_writes`, per-engine summaries)
//! must be **bit-identical** across `threads ∈ {1, 2, 4, 8}` *and* match
//! the on-line differential oracle `sched::oracle::run_reference`. Any
//! divergence — one ULP of timing, one event count — is a scheduler bug,
//! not a tolerance question; assertions print the failing seed like
//! `properties.rs` does.

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::traits::VertexProgram;
use repro::algo::{Bfs, PageRank, Sssp, Wcc};
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::sched::executor::NativeExecutor;
use repro::sched::{run_parallel_pooled, run_parallel_scoped, WorkerPool};
use repro::session::{JobSpec, Session};
use repro::util::SplitMix64;

mod common;
use common::{
    assert_bit_identical, default_threads, random_arch, random_graph, with_random_weights,
};

#[test]
fn prop_parallel_runs_bit_identical_across_threads_and_oracle() {
    // The PR-3 acceptance property.
    for seed in 300..310u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0x9A55);
        let source = rng.next_bounded(g.num_vertices as u64) as u32;
        let cfg = random_arch(&mut rng);
        let gw = with_random_weights(&g, &mut rng);
        let bfs = Bfs::new(source);
        let sssp = Sssp::new(source);
        let pagerank = PageRank::new(0.85, 4);
        let wcc = Wcc;
        let programs: [(&dyn VertexProgram, bool); 4] =
            [(&bfs, false), (&sssp, true), (&pagerank, false), (&wcc, false)];
        let acc = Accelerator::new(cfg.clone(), CostParams::default());
        for (program, weighted) in programs {
            let pre = acc
                .preprocess(if weighted { &gw } else { &g }, weighted)
                .unwrap();
            let base = acc
                .run_threaded(&pre, program, &mut NativeExecutor, 1)
                .unwrap()
                .run
                .unwrap();
            let oracle = repro::sched::oracle::run_reference(
                &cfg,
                &CostParams::default(),
                &pre,
                program,
                &mut NativeExecutor,
            )
            .unwrap();
            let ctx = format!("seed {seed} algo {} cfg {cfg:?}", program.name());
            assert_bit_identical(&base, &oracle, &format!("{ctx} [threads=1 vs oracle]"));
            for threads in [2usize, 4, 8] {
                let run = acc
                    .run_threaded(&pre, program, &mut NativeExecutor, threads)
                    .unwrap()
                    .run
                    .unwrap();
                assert_bit_identical(
                    &run,
                    &base,
                    &format!("{ctx} [threads={threads} vs threads=1]"),
                );
            }
        }
    }
}

#[test]
fn prop_parallel_determinism_under_wear_pressure() {
    // Tight endurance budgets drive the retire-then-repick path; the
    // dispatch pass's shadow crossbars must reach wear-out on exactly the
    // same op as the interpreter — or both runs must fail identically.
    for seed in 310..316u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0xE4D);
        let cfg = ArchConfig {
            total_engines: 4 + rng.next_bounded(8) as u32,
            static_engines: rng.next_bounded(3) as u32,
            ..ArchConfig::default()
        };
        let params = CostParams {
            endurance_cycles: 1.0 + rng.next_bounded(12) as f64,
            ..CostParams::default()
        };
        let acc = Accelerator::new(cfg.clone(), params.clone());
        let pre = acc.preprocess(&g, false).unwrap();
        let seq = acc.run_threaded(&pre, &Wcc, &mut NativeExecutor, 1);
        let par = acc.run_threaded(&pre, &Wcc, &mut NativeExecutor, 4);
        let ctx = format!("seed {seed} cfg {cfg:?} endurance {}", params.endurance_cycles);
        match (seq, par) {
            (Ok(a), Ok(b)) => {
                assert_bit_identical(&a.run.unwrap(), &b.run.unwrap(), &ctx)
            }
            (Err(ea), Err(eb)) => {
                assert_eq!(ea.to_string(), eb.to_string(), "{ctx}: errors diverge")
            }
            (a, b) => panic!(
                "{ctx}: one path failed, the other did not (seq ok = {}, par ok = {})",
                a.is_ok(),
                b.is_ok()
            ),
        }
    }
}

#[test]
fn prop_pooled_path_bit_identical_across_pool_sizes_and_reuse() {
    // The PR-4 acceptance property: a persistent pool must serve
    // bit-identical results at every worker count, with zero thread
    // spawns per superstep (worker ids stay fixed across whole runs) and
    // across consecutive runs on the same pool — and agree with the
    // scoped-spawn baseline it replaced.
    for seed in 320..326u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0xB07);
        let source = rng.next_bounded(g.num_vertices as u64) as u32;
        let cfg = random_arch(&mut rng);
        let acc = Accelerator::new(cfg.clone(), CostParams::default());
        let pre = acc.preprocess(&g, false).unwrap();
        let program = Bfs::new(source);
        let base = acc
            .run_threaded(&pre, &program, &mut NativeExecutor, 1)
            .unwrap()
            .run
            .unwrap();
        let scoped = run_parallel_scoped(
            &cfg,
            &CostParams::default(),
            &pre.plan,
            &program,
            &mut NativeExecutor,
            4,
        )
        .unwrap();
        let ctx = format!("seed {seed} cfg {cfg:?}");
        assert_bit_identical(&scoped, &base, &format!("{ctx} [scoped vs seq]"));
        for threads in [1usize, 2, 4, 8] {
            let mut pool = WorkerPool::new(threads);
            let ids = pool.worker_ids();
            for round in 0..2 {
                let run = run_parallel_pooled(
                    &cfg,
                    &CostParams::default(),
                    &pre.plan,
                    &program,
                    &mut NativeExecutor,
                    &mut pool,
                )
                .unwrap();
                assert_bit_identical(
                    &run,
                    &base,
                    &format!("{ctx} [pool={threads} round={round}]"),
                );
            }
            assert_eq!(
                pool.worker_ids(),
                ids,
                "{ctx}: pooled runs must not spawn threads"
            );
        }
    }
}

#[test]
fn session_pool_spawns_once_and_joins_on_drop() {
    // No leaked threads after Session drop, and consecutive runs reuse
    // the same pool workers with bit-identical results.
    let session = Session::builder().parallelism(4).build().unwrap();
    assert!(session.pool_liveness().is_none(), "pool is lazy");
    let spec = JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(6);
    let a = session.run(&spec).unwrap();
    let token = session.pool_liveness().expect("pool spawned");
    let b = session.run(&spec).unwrap();
    assert_bit_identical(
        &a.run.unwrap(),
        &b.run.unwrap(),
        "session pool reuse across consecutive runs",
    );
    assert!(token.upgrade().is_some(), "pool alive with the session");
    drop(session);
    assert!(
        token.upgrade().is_none(),
        "dropping the session must join every pool worker"
    );
}

#[test]
fn session_jobs_honor_the_harness_thread_default() {
    // The REPRO_THREADS-driven default (CI runs the suite at 1 and 4)
    // must serve results bit-identical to an explicitly sequential
    // session — through the full Session/ArtifactStore path. `.max(2)`
    // keeps the comparison parallel-vs-sequential even in the
    // REPRO_THREADS=1 leg.
    let threads = default_threads().max(2);
    let seq = Session::builder().parallelism(1).build().unwrap();
    let par = Session::builder().parallelism(threads).build().unwrap();
    for spec in [
        JobSpec::new(Dataset::Tiny, "bfs").with_source(3),
        JobSpec::new(Dataset::Tiny, "sssp").with_source(1),
        JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(6),
        JobSpec::new(Dataset::Tiny, "wcc"),
    ] {
        let a = seq.run(&spec).unwrap();
        let b = par.run(&spec).unwrap();
        let ctx = format!("{} at {threads} threads", spec.algorithm.as_str());
        assert_bit_identical(
            &a.run.unwrap(),
            &b.run.unwrap(),
            &format!("session {ctx}"),
        );
    }
}
