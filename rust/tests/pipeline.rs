//! Integration: the full preprocess → schedule → report pipeline across
//! algorithms, datasets and architecture configurations (native
//! executor; the PJRT path is covered in `pjrt.rs`).

use repro::accel::{Accelerator, ArchConfig, PolicyKind};
use repro::algo::traits::INF;
use repro::algo::{reference, Bfs, PageRank, Sssp, Wcc};
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::graph::Csr;
use repro::pattern::tables::ExecOrder;
use repro::sched::executor::NativeExecutor;

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if *g >= INF && *w >= INF {
            continue;
        }
        assert!((g - w).abs() <= tol, "{what}: vertex {i}: got {g}, want {w}");
    }
}

#[test]
fn all_algorithms_match_reference_on_gnutella() {
    let d = Dataset::Gnutella;
    let acc = Accelerator::with_defaults();

    let g = d.load().unwrap();
    let csr = Csr::from_coo(&g);

    let bfs = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor).unwrap();
    assert_close(
        &bfs.run.as_ref().unwrap().values,
        &reference::bfs_levels(&csr, 0),
        1e-3,
        "bfs",
    );

    let pr = acc
        .simulate(&g, &PageRank::new(0.85, 8), &mut NativeExecutor)
        .unwrap();
    assert_close(
        &pr.run.as_ref().unwrap().values,
        &reference::pagerank(&csr, 0.85, 8),
        1e-4,
        "pagerank",
    );

    let wcc = acc.simulate(&g, &Wcc, &mut NativeExecutor).unwrap();
    assert_close(
        &wcc.run.as_ref().unwrap().values,
        &reference::wcc_labels(&csr),
        0.0,
        "wcc",
    );

    let gw = d.load_weighted(1.0).unwrap();
    let csrw = Csr::from_coo(&gw);
    let sssp = acc.simulate(&gw, &Sssp::new(5), &mut NativeExecutor).unwrap();
    assert_close(
        &sssp.run.as_ref().unwrap().values,
        &reference::sssp_distances(&csrw, 5),
        1e-2,
        "sssp",
    );
}

#[test]
fn numeric_results_invariant_to_architecture() {
    // Engine allocation, policy, M, and execution order are performance
    // knobs — they must never change the computed values.
    let g = Dataset::Tiny.load().unwrap();
    let csr = Csr::from_coo(&g);
    let want = reference::bfs_levels(&csr, 3);
    let configs = [
        ArchConfig::default(),
        ArchConfig { static_engines: 0, ..ArchConfig::default() },
        ArchConfig { static_engines: 31, ..ArchConfig::default() },
        ArchConfig { crossbars_per_engine: 4, total_engines: 6, static_engines: 4, ..ArchConfig::default() },
        ArchConfig { policy: PolicyKind::RoundRobin, ..ArchConfig::default() },
        ArchConfig { policy: PolicyKind::Random, ..ArchConfig::default() },
        ArchConfig { order: ExecOrder::RowMajor, ..ArchConfig::default() },
        ArchConfig { crossbar_size: 8, ..ArchConfig::default() },
        ArchConfig { dynamic_reuse: true, ..ArchConfig::default() },
    ];
    for (i, cfg) in configs.into_iter().enumerate() {
        let acc = Accelerator::new(cfg, CostParams::default());
        let r = acc.simulate(&g, &Bfs::new(3), &mut NativeExecutor).unwrap();
        assert_close(&r.run.as_ref().unwrap().values, &want, 1e-3, &format!("config {i}"));
    }
}

#[test]
fn dynamic_reuse_extension_reduces_writes() {
    let g = Dataset::Tiny.load().unwrap();
    let base = ArchConfig { static_engines: 0, ..ArchConfig::default() };
    let with_reuse = ArchConfig { dynamic_reuse: true, ..base.clone() };
    let r0 = Accelerator::new(base, CostParams::default())
        .simulate(&g, &Bfs::new(0), &mut NativeExecutor)
        .unwrap();
    let r1 = Accelerator::new(with_reuse, CostParams::default())
        .simulate(&g, &Bfs::new(0), &mut NativeExecutor)
        .unwrap();
    assert!(
        r1.counts.write_bits < r0.counts.write_bits,
        "reuse {} !< baseline {}",
        r1.counts.write_bits,
        r0.counts.write_bits
    );
}

#[test]
fn static_coverage_grows_with_capacity() {
    let g = Dataset::WikiVote.load().unwrap();
    let mut last = -1.0;
    for n in [0u32, 4, 16, 31] {
        let cfg = ArchConfig { static_engines: n, ..ArchConfig::default() };
        let acc = Accelerator::new(cfg, CostParams::default());
        let pre = acc.preprocess(&g, false).unwrap();
        let cov = pre.static_coverage();
        assert!(cov >= last, "coverage not monotone at N={n}");
        last = cov;
    }
    assert!(last > 0.5, "top-31 patterns should cover most subgraphs");
}

#[test]
fn wiki_vote_top16_coverage_is_paper_scale() {
    // Paper Fig. 1a: top-16 patterns cover 86% of Wiki-Vote subgraphs.
    // Our R-MAT stand-in must land in the same regime (>60%).
    let g = Dataset::WikiVote.load().unwrap();
    let acc = Accelerator::with_defaults();
    let pre = acc.preprocess(&g, false).unwrap();
    let cov = pre.ranking.coverage(16);
    assert!(cov > 0.6, "top-16 coverage {cov:.3}");
    // And single-edge patterns dominate the head of the ranking.
    assert_eq!(pre.ranking.ranked[0].0.nnz(), 1);
}

#[test]
fn multi_crossbar_engines_absorb_more_static_patterns() {
    let g = Dataset::Tiny.load().unwrap();
    let m1 = ArchConfig { total_engines: 6, static_engines: 4, crossbars_per_engine: 1, ..ArchConfig::default() };
    let m4 = ArchConfig { crossbars_per_engine: 4, ..m1.clone() };
    let p1 = Accelerator::new(m1, CostParams::default()).preprocess(&g, false).unwrap();
    let p4 = Accelerator::new(m4, CostParams::default()).preprocess(&g, false).unwrap();
    assert!(p4.static_coverage() > p1.static_coverage());
}

#[test]
fn report_counts_are_consistent() {
    let g = Dataset::Tiny.load().unwrap();
    let acc = Accelerator::with_defaults();
    let r = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor).unwrap();
    let run = r.run.as_ref().unwrap();
    assert_eq!(run.static_ops + run.dynamic_ops, run.counts.mvm_ops);
    // Every subgraph op digitizes C bitlines.
    assert_eq!(r.counts.adc_ops, run.counts.mvm_ops.checked_mul(4).unwrap() + r.init_counts_adc());
    // Energy total equals sum of components.
    let e = &r.energy;
    let total = e.reram_read_j + e.reram_write_j + e.sram_j + e.adc_j + e.alu_j + e.main_mem_j;
    assert!((total - r.energy_j()).abs() < 1e-18);
}

// Helper trait so the test can reason about init ADC ops (none today, but
// keeps the assertion honest if initialization ever samples ADCs).
trait InitAdc {
    fn init_counts_adc(&self) -> u64;
}
impl InitAdc for repro::accel::SimReport {
    fn init_counts_adc(&self) -> u64 {
        self.run
            .as_ref()
            .map(|r| r.init_counts.adc_ops)
            .unwrap_or(0)
    }
}
