//! Integration: the AOT/PJRT production datapath.
//!
//! Requires `make artifacts`. These tests are the proof that the three
//! layers compose: JAX/Pallas kernels lowered to HLO text, loaded by the
//! xla crate on the PJRT CPU client, driven by the rust scheduler, and
//! numerically indistinguishable from both the native mirror and the
//! pure-CPU references.

#![cfg(feature = "pjrt")]

use repro::accel::{Accelerator, ArchConfig};
use repro::algo::traits::{StepKind, INF};
use repro::algo::{reference, Bfs, PageRank, Sssp, Wcc};
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::graph::Csr;
use repro::runtime::{Manifest, PjrtExecutor};
use repro::sched::executor::{NativeExecutor, StepExecutor};
use repro::sched::ExecutionPlan;
use repro::util::SplitMix64;

fn artifacts_present() -> bool {
    repro::runtime::default_artifact_dir()
        .join("manifest.tsv")
        .exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_present() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn manifest_covers_every_step_kind() {
    require_artifacts!();
    let m = Manifest::load(&repro::runtime::default_artifact_dir()).unwrap();
    for kind in [
        StepKind::Bfs,
        StepKind::Sssp,
        StepKind::Wcc,
        StepKind::PageRank,
        StepKind::Mvm,
    ] {
        assert!(
            m.select(kind.artifact_name(), 4).is_some(),
            "missing artifact for {kind:?} at C=4"
        );
    }
    // The 8x8 ablation and the Fig. 3 (C=2) variants exist too.
    assert!(m.select("bfs", 8).is_some());
    assert!(m.select("bfs", 2).is_some());
}

#[test]
fn pjrt_equals_native_on_random_batches() {
    require_artifacts!();
    let mut pjrt = PjrtExecutor::from_default_dir().unwrap();
    let g = Dataset::Tiny.load().unwrap();
    for c in [4usize, 8] {
        let part = repro::pattern::extract::partition(&g, c, false);
        let plan = ExecutionPlan::from_partitioned(&part);
        let n = part.num_subgraphs().min(300);
        let sgs: Vec<u32> = (0..n as u32).collect();
        let mut rng = SplitMix64::new(c as u64);
        for kind in [StepKind::Bfs, StepKind::Wcc, StepKind::PageRank, StepKind::Mvm] {
            let xs: Vec<f32> = (0..n * c)
                .map(|_| {
                    if kind == StepKind::PageRank || kind == StepKind::Mvm {
                        rng.next_f32()
                    } else if rng.next_bool(0.4) {
                        INF
                    } else {
                        (rng.next_f32() * 10.0).floor()
                    }
                })
                .collect();
            let mut got = Vec::new();
            let mut want = Vec::new();
            pjrt.execute(kind, plan.batch(&sgs), &xs, &mut got).unwrap();
            NativeExecutor.execute(kind, plan.batch(&sgs), &xs, &mut want).unwrap();
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                let ok = (a - b).abs() < 1e-4 || (*a >= INF && *b >= INF);
                assert!(ok, "{kind:?} c={c} lane {i}: pjrt {a} native {b}");
            }
        }
    }
}

#[test]
fn pjrt_sssp_uses_weights() {
    require_artifacts!();
    let mut pjrt = PjrtExecutor::from_default_dir().unwrap();
    let g = Dataset::Tiny.load_weighted(1.0).unwrap();
    let part = repro::pattern::extract::partition(&g, 4, true);
    let plan = ExecutionPlan::from_partitioned(&part);
    let n = part.num_subgraphs().min(200);
    let sgs: Vec<u32> = (0..n as u32).collect();
    let mut rng = SplitMix64::new(11);
    let xs: Vec<f32> = (0..n * 4)
        .map(|_| if rng.next_bool(0.5) { INF } else { rng.next_f32() * 4.0 })
        .collect();
    let mut got = Vec::new();
    let mut want = Vec::new();
    pjrt.execute(StepKind::Sssp, plan.batch(&sgs), &xs, &mut got).unwrap();
    NativeExecutor.execute(StepKind::Sssp, plan.batch(&sgs), &xs, &mut want).unwrap();
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-3 || (*a >= INF && *b >= INF), "{a} vs {b}");
    }
}

#[test]
fn full_bfs_through_pjrt_matches_cpu_reference() {
    require_artifacts!();
    let g = Dataset::Tiny.load().unwrap();
    let acc = Accelerator::with_defaults();
    let mut pjrt = PjrtExecutor::from_default_dir().unwrap();
    let r = acc.simulate(&g, &Bfs::new(0), &mut pjrt).unwrap();
    let want = reference::bfs_levels(&Csr::from_coo(&g), 0);
    for (got, want) in r.run.as_ref().unwrap().values.iter().zip(&want) {
        assert!(
            (got - want).abs() < 1e-3 || (*got >= INF && *want >= INF),
            "{got} vs {want}"
        );
    }
    assert!(pjrt.runtime.dispatches > 0, "PJRT was never dispatched");
}

#[test]
fn full_pagerank_and_wcc_through_pjrt() {
    require_artifacts!();
    let g = Dataset::Tiny.load().unwrap();
    let csr = Csr::from_coo(&g);
    let acc = Accelerator::with_defaults();
    let mut pjrt = PjrtExecutor::from_default_dir().unwrap();

    let pr = acc.simulate(&g, &PageRank::new(0.85, 6), &mut pjrt).unwrap();
    let want = reference::pagerank(&csr, 0.85, 6);
    for (got, want) in pr.run.as_ref().unwrap().values.iter().zip(&want) {
        assert!((got - want).abs() < 1e-4, "pagerank {got} vs {want}");
    }

    let wcc = acc.simulate(&g, &Wcc, &mut pjrt).unwrap();
    let want = reference::wcc_labels(&csr);
    for (got, want) in wcc.run.as_ref().unwrap().values.iter().zip(&want) {
        assert_eq!(got, want, "wcc label mismatch");
    }
}

#[test]
fn full_sssp_through_pjrt() {
    require_artifacts!();
    let g = Dataset::Tiny.load_weighted(1.0).unwrap();
    let acc = Accelerator::with_defaults();
    let mut pjrt = PjrtExecutor::from_default_dir().unwrap();
    let r = acc.simulate(&g, &Sssp::new(2), &mut pjrt).unwrap();
    let want = reference::sssp_distances(&Csr::from_coo(&g), 2);
    for (got, want) in r.run.as_ref().unwrap().values.iter().zip(&want) {
        assert!(
            (got - want).abs() < 1e-2 || (*got >= INF && *want >= INF),
            "{got} vs {want}"
        );
    }
}

#[test]
fn pjrt_8x8_crossbar_configuration() {
    require_artifacts!();
    let g = Dataset::Tiny.load().unwrap();
    let cfg = ArchConfig { crossbar_size: 8, ..ArchConfig::default() };
    let acc = Accelerator::new(cfg, CostParams::default());
    let mut pjrt = PjrtExecutor::from_default_dir().unwrap();
    let r = acc.simulate(&g, &Bfs::new(0), &mut pjrt).unwrap();
    let want = reference::bfs_levels(&Csr::from_coo(&g), 0);
    for (got, want) in r.run.as_ref().unwrap().values.iter().zip(&want) {
        assert!((got - want).abs() < 1e-3 || (*got >= INF && *want >= INF));
    }
}

#[test]
fn missing_artifact_is_a_clean_error() {
    require_artifacts!();
    let mut pjrt = PjrtExecutor::from_default_dir().unwrap();
    // C=3 has no artifact variant.
    let g = Dataset::Tiny.load().unwrap();
    let part = repro::pattern::extract::partition(&g, 3, false);
    let plan = ExecutionPlan::from_partitioned(&part);
    let mut out = Vec::new();
    let err = pjrt
        .execute(StepKind::Bfs, plan.batch(&[0]), &[0.0, 0.0, 0.0], &mut out)
        .unwrap_err();
    assert!(err.to_string().contains("no artifact"), "unexpected error: {err}");
}

#[test]
fn service_honors_pjrt_backend_end_to_end() {
    // The serve-path backend gap: a PJRT-configured service must route
    // worker jobs through the PJRT executor (not NativeExecutor) and
    // produce reference-correct results.
    use repro::coordinator::{Service, ServiceConfig};
    use repro::session::{Backend, JobSpec};
    require_artifacts!();
    let svc = Service::spawn(ServiceConfig {
        backend: Backend::pjrt_default(),
        workers: 2,
        ..ServiceConfig::default()
    })
    .unwrap();
    let r = svc
        .submit_blocking(JobSpec::new(Dataset::Tiny, "bfs"))
        .unwrap();
    let want = reference::bfs_levels(&Csr::from_coo(&Dataset::Tiny.load().unwrap()), 0);
    for (got, want) in r.report.run.as_ref().unwrap().values.iter().zip(&want) {
        assert!((got - want).abs() < 1e-3 || (*got >= INF && *want >= INF));
    }
}
