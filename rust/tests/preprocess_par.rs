//! Property suite for the parallel cold-preprocess pipeline
//! (`Accelerator::preprocess_threaded` / `preprocess_pooled` and the
//! chunked partition underneath it).
//!
//! The contract under test: **chunk boundaries and thread counts are
//! implementation details that may never leak into any output.** The
//! parallel [`Preprocessed`] must be whole-struct `PartialEq`-equal to
//! the sequential one for every thread count and chunk size; a
//! parallel-compiled artifact must survive the disk round trip, feed
//! the DSE static-slot rebuild, and accept delta patches exactly as a
//! sequentially compiled one does.

use repro::accel::Accelerator;
use repro::accel::ArchConfig;
use repro::algo::{Bfs, PageRank, Sssp, Wcc};
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::sched::{patch_preprocessed, WorkerPool};
use repro::session::{ArtifactKey, DiskStore};
use repro::util::SplitMix64;

mod common;
use common::{
    assert_chunked_partition_matches, random_arch, random_delta_batch, random_graph, scratch_dir,
    with_random_weights,
};

/// A disposable key for graphs that don't come from a `Dataset` preset
/// (same rationale as the artifact-IO suite: only the arch part must be
/// honest because `load` verifies `plan.matches`).
fn test_key(seed: u64, weighted: bool, arch: &ArchConfig) -> ArtifactKey {
    let scale = 1.0 - (seed % 7) as f64 * 1e-3;
    ArtifactKey::new(Dataset::Tiny, scale, weighted, arch)
}

/// The thread-count axis: sequential baseline, the two CI lane counts,
/// and an oversubscribed count (more workers than chunks on the small
/// graphs — exercises the empty-chunk edge).
const THREADS: [usize; 4] = [1, 2, 4, 8];

#[test]
fn prop_parallel_preprocess_matches_sequential_for_every_thread_and_chunk_count() {
    for seed in 540..546u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0x9A11);
        let arch = random_arch(&mut rng);
        let gw = with_random_weights(&g, &mut rng);
        let acc = Accelerator::new(arch.clone(), CostParams::default());
        for (graph, weighted) in [(&g, false), (&gw, true)] {
            let ctx = format!("seed {seed} weighted {weighted} arch {arch:?}");

            // The partition layer first: every chunk size against the
            // monolithic oracle.
            assert_chunked_partition_matches(graph, arch.crossbar_size, weighted, &ctx);

            // Then the full pipeline at every thread count.
            let want = acc.preprocess(graph, weighted).unwrap();
            for threads in THREADS {
                let got = acc.preprocess_threaded(graph, weighted, threads).unwrap();
                assert_eq!(got.part, want.part, "{ctx} threads {threads}: Partitioned");
                assert_eq!(got.ranking, want.ranking, "{ctx} threads {threads}: PatternRanking");
                assert_eq!(got.ct, want.ct, "{ctx} threads {threads}: ConfigTable");
                assert_eq!(got.st, want.st, "{ctx} threads {threads}: SubgraphTable");
                assert_eq!(got.plan, want.plan, "{ctx} threads {threads}: ExecutionPlan");
                assert_eq!(got, want, "{ctx} threads {threads}: Preprocessed");
            }

            // And the pooled entry point: one long-lived pool across
            // both weighted variants and repeated compiles, the way the
            // session's free list actually reuses workers.
            let mut pool = WorkerPool::new(4);
            for round in 0..2 {
                let got = acc.preprocess_pooled(graph, weighted, &mut pool).unwrap();
                assert_eq!(got, want, "{ctx} pooled round {round}: Preprocessed");
            }
        }
    }
}

#[test]
fn prop_parallel_compiled_artifact_round_trips_identically() {
    // Disk parity: an artifact compiled on 4 workers, saved, and loaded
    // back must equal the sequential compile — the serialized bytes
    // carry no trace of how the compile was parallelized.
    for seed in 546..550u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0xD15C);
        let arch = random_arch(&mut rng);
        let gw = with_random_weights(&g, &mut rng);
        let acc = Accelerator::new(arch.clone(), CostParams::default());
        let dir = scratch_dir("par-roundtrip");
        let store = DiskStore::open(&dir).unwrap();
        for (graph, weighted) in [(&g, false), (&gw, true)] {
            let ctx = format!("seed {seed} weighted {weighted} arch {arch:?}");
            let want = acc.preprocess(graph, weighted).unwrap();
            let par = acc.preprocess_threaded(graph, weighted, 4).unwrap();
            let key = test_key(seed, weighted, &arch);
            assert!(store.save(&key, &par).unwrap(), "{ctx}: first save writes");
            let loaded = store.load(&key, &arch).unwrap();
            assert_eq!(loaded, want, "{ctx}: loaded parallel artifact vs sequential compile");
            store.remove(&key);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn parallel_artifact_feeds_dse_rebuild_identically() {
    // DSE sweeps call `rebuild_static_slots` on a scratch copy of the
    // artifact across every candidate static split N; a parallel-compiled
    // artifact must sweep to the identical optimum and identical
    // per-point numbers.
    let g = Dataset::Tiny.load().unwrap();
    let arch = ArchConfig::default();
    let params = CostParams::default();
    let acc = Accelerator::new(arch.clone(), params.clone());
    let seq = acc.preprocess(&g, false).unwrap();
    let par = acc.preprocess_threaded(&g, false, 4).unwrap();
    assert_eq!(par, seq, "parallel compile diverges before the sweep even starts");

    let program = Bfs::new(0);
    let mut scratch_a = seq;
    let mut scratch_b = par;
    let (best_a, points_a) =
        repro::dse::find_best_static_split_with(&mut scratch_a, &arch, &params, &program, None)
            .unwrap();
    let (best_b, points_b) =
        repro::dse::find_best_static_split_with(&mut scratch_b, &arch, &params, &program, None)
            .unwrap();
    assert_eq!(best_a, best_b, "best split diverges");
    assert_eq!(points_a.len(), points_b.len());
    for (pa, pb) in points_a.iter().zip(&points_b) {
        assert_eq!(pa.x, pb.x);
        assert_eq!(pa.exec_time_ns, pb.exec_time_ns, "N={}: time", pa.x);
        assert_eq!(pa.energy_j, pb.energy_j, "N={}: energy", pa.x);
        assert_eq!(pa.write_bits, pb.write_bits, "N={}: writes", pa.x);
        assert_eq!(pa.static_hit_rate, pb.static_hit_rate, "N={}: hit rate", pa.x);
        assert_eq!(pa.speedup, pb.speedup, "N={}: speedup", pa.x);
    }
}

#[test]
fn prop_delta_patch_after_parallel_compile_is_bit_identical_to_cold_recompile() {
    // The streaming-mutation path composed with the parallel compile:
    // patching a parallel-compiled artifact must land on exactly the
    // artifact a cold (sequential) recompile of the mutated graph
    // produces — same whole-struct equality the delta suite enforces for
    // sequential compiles.
    for seed in 550..556u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0xDE17A);
        let arch = random_arch(&mut rng);
        let gw = with_random_weights(&g, &mut rng);
        let acc = Accelerator::new(arch.clone(), CostParams::default());
        for (graph, weighted) in [(&g, false), (&gw, true)] {
            let ctx = format!("seed {seed} weighted {weighted} arch {arch:?}");
            let mut patched = acc.preprocess_threaded(graph, weighted, 4).unwrap();
            let batch = random_delta_batch(graph, &mut rng);
            patch_preprocessed(&mut patched, &batch, &acc.config).unwrap();
            let cold = acc
                .preprocess(&batch.apply_to_coo(graph).unwrap(), weighted)
                .unwrap();
            assert_eq!(patched, cold, "{ctx}: patched parallel artifact vs cold recompile");
        }
    }
}

#[test]
fn parallel_preprocess_runs_all_four_algorithms_identically() {
    // End-to-end sanity: the plan a parallel compile produces drives all
    // four vertex programs to bit-identical results. Whole-struct
    // equality already implies this; this test pins the user-visible
    // consequence so a future relaxation of `PartialEq` on
    // `Preprocessed` can't silently weaken the contract.
    use repro::algo::traits::VertexProgram;
    use repro::sched::executor::NativeExecutor;

    let seed = 560u64;
    let g = random_graph(seed);
    let mut rng = SplitMix64::new(seed ^ 0xA160);
    let arch = random_arch(&mut rng);
    let gw = with_random_weights(&g, &mut rng);
    let source = rng.next_bounded(g.num_vertices as u64) as u32;
    let acc = Accelerator::new(arch.clone(), CostParams::default());
    let bfs = Bfs::new(source);
    let sssp = Sssp::new(source);
    let pagerank = PageRank::new(0.85, 4);
    let wcc = Wcc;
    let programs: [(&dyn VertexProgram, bool); 4] =
        [(&bfs, false), (&sssp, true), (&pagerank, false), (&wcc, false)];
    for (program, weighted) in programs {
        let graph = if weighted { &gw } else { &g };
        let want = acc.preprocess(graph, weighted).unwrap();
        let par = acc.preprocess_threaded(graph, weighted, 4).unwrap();
        let ctx = format!("seed {seed} algo {}", program.name());
        let a = acc.run_threaded(&want, program, &mut NativeExecutor, 1).unwrap().run.unwrap();
        let b = acc.run_threaded(&par, program, &mut NativeExecutor, 1).unwrap().run.unwrap();
        common::assert_bit_identical(&b, &a, &ctx);
    }
}
