//! Property-based tests over randomized inputs (seeded SplitMix64 — the
//! offline image vendors no proptest, so these are explicit-seed
//! property sweeps: every case prints its seed on failure).

use repro::accel::{Accelerator, ArchConfig, PolicyKind};
use repro::algo::traits::{VertexProgram, INF};
use repro::algo::{reference, Bfs, PageRank, Sssp, Wcc};
use repro::cost::CostParams;
use repro::graph::coo::Coo;
use repro::graph::generator::erdos_renyi;
use repro::graph::Csr;
use repro::pattern::extract::partition;
use repro::pattern::rank::PatternRanking;
use repro::pattern::tables::{ConfigTable, ExecOrder, SubgraphTable};
use repro::sched::executor::NativeExecutor;
use repro::util::SplitMix64;

mod common;
use common::{random_graph, with_random_weights};

#[test]
fn prop_partition_preserves_edges() {
    for seed in 0..40u64 {
        let g = random_graph(seed);
        for c in [2usize, 3, 4, 5, 8] {
            let p = partition(&g, c, false);
            let nnz: u64 = p.subgraphs.iter().map(|s| s.pattern.nnz() as u64).sum();
            assert_eq!(nnz as usize, g.num_edges(), "seed {seed} c {c}");
            // No empty windows, block coords in range.
            let nb = p.num_blocks();
            for s in &p.subgraphs {
                assert!(!s.pattern.is_empty(), "seed {seed}: empty window kept");
                assert!(s.brow < nb && s.bcol < nb, "seed {seed}: block out of range");
            }
        }
    }
}

#[test]
fn prop_ranking_counts_sum_to_subgraphs() {
    for seed in 40..70u64 {
        let g = random_graph(seed);
        let p = partition(&g, 4, false);
        let r = PatternRanking::from_partitioned(&p);
        let total: u64 = r.ranked.iter().map(|&(_, c)| c as u64).sum();
        assert_eq!(total as usize, p.num_subgraphs(), "seed {seed}");
        // Ranked counts are non-increasing.
        for w in r.ranked.windows(2) {
            assert!(w[0].1 >= w[1].1, "seed {seed}: ranking not sorted");
        }
        // coverage is monotone in k.
        let mut last = 0.0;
        for k in 0..r.num_patterns().min(32) {
            let c = r.coverage(k);
            assert!(c >= last - 1e-12, "seed {seed}: coverage not monotone");
            last = c;
        }
    }
}

#[test]
fn prop_tables_are_consistent() {
    for seed in 70..95u64 {
        let g = random_graph(seed);
        let p = partition(&g, 4, false);
        let r = PatternRanking::from_partitioned(&p);
        let mut rng = SplitMix64::new(seed ^ 0xABCD);
        let n_static = rng.next_bounded(8) as u32;
        let m = 1 + rng.next_bounded(4) as u32;
        let assignment = if rng.next_bool(0.5) {
            repro::pattern::tables::StaticAssignment::TopK
        } else {
            repro::pattern::tables::StaticAssignment::Balanced
        };
        let ct = ConfigTable::build(&r, 4, n_static, m, 4, assignment);
        // Static slots unique and within range.
        let mut seen = std::collections::HashSet::new();
        for (_, slot) in ct.static_assignments() {
            assert!(slot.engine < n_static.max(1), "seed {seed}");
            assert!(slot.crossbar < m, "seed {seed}");
            assert!(seen.insert((slot.engine, slot.crossbar)), "seed {seed}: slot reused");
        }
        assert!(seen.len() <= (n_static * m) as usize);
        // ST covers every subgraph exactly once, groups share major key.
        for order in [ExecOrder::ColumnMajor, ExecOrder::RowMajor] {
            let st = SubgraphTable::build(&p, &r, order);
            assert_eq!(st.len(), p.num_subgraphs(), "seed {seed}");
            let mut covered = vec![false; p.num_subgraphs()];
            for grp in st.iter_groups() {
                let key0 = match order {
                    ExecOrder::ColumnMajor => grp[0].dst_start,
                    ExecOrder::RowMajor => grp[0].src_start,
                };
                for e in grp {
                    let key = match order {
                        ExecOrder::ColumnMajor => e.dst_start,
                        ExecOrder::RowMajor => e.src_start,
                    };
                    assert_eq!(key, key0, "seed {seed}: mixed group");
                    assert!(!covered[e.sg_idx as usize], "seed {seed}: duplicate");
                    covered[e.sg_idx as usize] = true;
                }
            }
            assert!(covered.iter().all(|&c| c), "seed {seed}: missing subgraph");
        }
    }
}

#[test]
fn prop_accelerator_bfs_equals_reference() {
    // The headline correctness property across random graphs, sources,
    // window sizes and engine splits.
    for seed in 95..120u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0x5EED);
        let source = rng.next_bounded(g.num_vertices as u64) as u32;
        let cfg = ArchConfig {
            crossbar_size: [2, 4, 8][rng.next_index(3)],
            total_engines: 4 + rng.next_bounded(28) as u32,
            static_engines: 0, // set below
            policy: [PolicyKind::Lru, PolicyKind::RoundRobin, PolicyKind::Lfu]
                [rng.next_index(3)],
            ..ArchConfig::default()
        };
        let cfg = ArchConfig {
            static_engines: rng.next_bounded(cfg.total_engines as u64 + 1) as u32,
            ..cfg
        };
        let acc = Accelerator::new(cfg.clone(), CostParams::default());
        let r = acc.simulate(&g, &Bfs::new(source), &mut NativeExecutor).unwrap();
        let want = reference::bfs_levels(&Csr::from_coo(&g), source);
        for (v, (got, want)) in r.run.as_ref().unwrap().values.iter().zip(&want).enumerate()
        {
            let ok = (got - want).abs() < 1e-3 || (*got >= INF && *want >= INF);
            assert!(ok, "seed {seed} cfg {cfg:?} vertex {v}: got {got} want {want}");
        }
        // Conservation: every op is static or dynamic.
        let run = r.run.as_ref().unwrap();
        assert_eq!(run.static_ops + run.dynamic_ops, run.counts.mvm_ops, "seed {seed}");
    }
}

#[test]
fn prop_write_bits_zero_when_everything_static() {
    // If capacity >= distinct patterns, runtime must be write-free.
    for seed in 120..140u64 {
        let g = random_graph(seed);
        let p = partition(&g, 4, false);
        let r = PatternRanking::from_partitioned(&p);
        let patterns = r.num_patterns() as u32;
        if patterns == 0 || patterns > 256 {
            continue;
        }
        let cfg = ArchConfig {
            total_engines: patterns + 1,
            static_engines: patterns,
            crossbars_per_engine: 1,
            // TopK guarantees one slot per distinct pattern; Balanced
            // may spend slots on replicas of hot patterns instead.
            static_assignment: repro::pattern::tables::StaticAssignment::TopK,
            ..ArchConfig::default()
        };
        let acc = Accelerator::new(cfg, CostParams::default());
        let rep = acc.simulate(&g, &Bfs::new(0), &mut NativeExecutor).unwrap();
        let run = rep.run.as_ref().unwrap();
        assert_eq!(run.counts.write_bits, 0, "seed {seed}: runtime writes");
        assert_eq!(run.dynamic_ops, 0, "seed {seed}");
        assert!((rep.static_hit_rate - 1.0).abs() < 1e-12, "seed {seed}");
    }
}

#[test]
fn prop_symmetrize_partition_transpose_symmetry() {
    // For an undirected graph, the window multiset is symmetric:
    // pattern(brow,bcol) is the transpose of pattern(bcol,brow).
    for seed in 140..155u64 {
        let g = random_graph(seed).symmetrize();
        let p = partition(&g, 4, false);
        let map: std::collections::HashMap<(u32, u32), repro::pattern::Pattern> =
            p.subgraphs.iter().map(|s| ((s.brow, s.bcol), s.pattern)).collect();
        for s in &p.subgraphs {
            let mirror = map
                .get(&(s.bcol, s.brow))
                .unwrap_or_else(|| panic!("seed {seed}: missing mirror window"));
            // transpose bit-by-bit
            let mut transposed = repro::pattern::Pattern::EMPTY;
            for (i, j) in mirror.cells(4) {
                transposed = transposed.with_edge(j as usize, i as usize, 4);
            }
            assert_eq!(transposed, s.pattern, "seed {seed}: asymmetric windows");
        }
    }
}

#[test]
fn prop_plan_interpreter_matches_reference_scheduler() {
    // The PR-2 acceptance property: interpreting the compiled
    // `ExecutionPlan` must be *bit-identical* to the seed scheduler's
    // on-line table-scanning derivation (retained in `sched::oracle`) —
    // same values, same event counts, same timing, same static/dynamic
    // split — across random graphs, architectures and all four
    // algorithms.
    for seed in 200..216u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0x9A7);
        let source = rng.next_bounded(g.num_vertices as u64) as u32;
        let cfg = ArchConfig {
            crossbar_size: [2, 4, 8][rng.next_index(3)],
            total_engines: 4 + rng.next_bounded(28) as u32,
            policy: [
                PolicyKind::Lru,
                PolicyKind::RoundRobin,
                PolicyKind::Lfu,
                PolicyKind::Random,
            ][rng.next_index(4)],
            dynamic_reuse: rng.next_bool(0.5),
            order: if rng.next_bool(0.5) {
                ExecOrder::ColumnMajor
            } else {
                ExecOrder::RowMajor
            },
            ..ArchConfig::default()
        };
        let cfg = ArchConfig {
            static_engines: rng.next_bounded(cfg.total_engines as u64) as u32,
            ..cfg
        };
        // Random edge weights so the SSSP case exercises real weight data.
        let gw = with_random_weights(&g, &mut rng);
        let bfs = Bfs::new(source);
        let sssp = Sssp::new(source);
        let pagerank = PageRank::new(0.85, 4);
        let wcc = Wcc;
        let programs: [(&dyn VertexProgram, bool); 4] =
            [(&bfs, false), (&sssp, true), (&pagerank, false), (&wcc, false)];
        let acc = Accelerator::new(cfg.clone(), CostParams::default());
        for (program, weighted) in programs {
            let pre = acc
                .preprocess(if weighted { &gw } else { &g }, weighted)
                .unwrap();
            let planned = acc
                .run(&pre, program, &mut NativeExecutor)
                .unwrap()
                .run
                .unwrap();
            let oracle = repro::sched::oracle::run_reference(
                &cfg,
                &CostParams::default(),
                &pre,
                program,
                &mut NativeExecutor,
            )
            .unwrap();
            let ctx = format!("seed {seed} algo {} cfg {cfg:?}", program.name());
            assert_eq!(planned.values, oracle.values, "{ctx}: values diverge");
            assert_eq!(planned.counts, oracle.counts, "{ctx}: event counts diverge");
            assert_eq!(planned.init_counts, oracle.init_counts, "{ctx}: init counts");
            assert_eq!(planned.static_ops, oracle.static_ops, "{ctx}: static ops");
            assert_eq!(planned.dynamic_ops, oracle.dynamic_ops, "{ctx}: dynamic ops");
            assert_eq!(planned.dynamic_hits, oracle.dynamic_hits, "{ctx}: dynamic hits");
            assert_eq!(planned.iterations, oracle.iterations, "{ctx}: iterations");
            assert_eq!(planned.supersteps, oracle.supersteps, "{ctx}: supersteps");
            assert_eq!(
                planned.exec_time_ns, oracle.exec_time_ns,
                "{ctx}: modeled time diverges"
            );
            assert_eq!(
                planned.static_hit_rate(),
                oracle.static_hit_rate(),
                "{ctx}: static hit rate"
            );
            assert_eq!(
                planned.max_dynamic_cell_writes, oracle.max_dynamic_cell_writes,
                "{ctx}: wear"
            );
        }
    }
}

#[test]
fn prop_energy_monotone_in_work() {
    // Adding edges can only increase total modeled energy.
    for seed in 155..170u64 {
        let mut rng = SplitMix64::new(seed);
        let n = 64 + rng.next_bounded(192) as u32;
        let base_edges = (n as usize) * 2;
        let g1 = erdos_renyi(n, base_edges, seed);
        let mut extra = g1.edges.clone();
        let g2e = erdos_renyi(n, base_edges * 2, seed ^ 1);
        extra.extend_from_slice(&g2e.edges);
        let g2 = Coo::from_edges(n, extra);
        assert!(g2.num_edges() >= g1.num_edges());
        let acc = Accelerator::with_defaults();
        let r1 = acc.simulate(&g1, &repro::algo::PageRank::new(0.85, 3), &mut NativeExecutor).unwrap();
        let r2 = acc.simulate(&g2, &repro::algo::PageRank::new(0.85, 3), &mut NativeExecutor).unwrap();
        assert!(
            r2.energy_j() >= r1.energy_j() * 0.99,
            "seed {seed}: energy shrank with more edges"
        );
    }
}
