//! Integration suite for the hardened serve queue: panic isolation,
//! request coalescing (bit-identity + the execution-count proof),
//! deadline load-shedding, priority ordering, bounded-queue
//! backpressure, batch-submit handle recovery, and the metrics
//! conservation invariant `submitted == completed + failed + shed`
//! under hostile randomized bursts.
//!
//! The instruments are registry entries, not mocks of the service:
//! a `gate` program that parks the worker on a barrier mid-execution
//! (so the test controls exactly what is queued behind it), a `count`
//! program whose factory counts instantiations (executions, not
//! completions — the coalescing discriminator), order-recording `lo`/
//! `hi` programs, and a `boom` factory that panics. All of them
//! delegate the actual graph work to the builtin BFS program, so every
//! result stays reference-checked by the rest of the suite.

mod common;
use common::{default_shards, default_threads};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use repro::algo::{AlgoParams, AlgorithmRegistry, Bfs, BoxedProgram, Semiring, StepKind, VertexProgram};
use repro::coordinator::{JobError, LatencySummary, Service};
use repro::graph::datasets::Dataset;
use repro::session::{JobSpec, Session};
use repro::util::SplitMix64;

/// BFS that parks the executing worker on a shared barrier at `init`
/// time. The test thread releases it with `gate.wait()` — until then
/// the worker is provably mid-execution and everything submitted after
/// it is provably queued.
struct GateBfs {
    inner: Bfs,
    gate: Arc<Barrier>,
}

impl VertexProgram for GateBfs {
    fn name(&self) -> &'static str {
        "gate-bfs"
    }

    fn semiring(&self) -> Semiring {
        self.inner.semiring()
    }

    fn step_kind(&self) -> StepKind {
        self.inner.step_kind()
    }

    fn init(&self, num_vertices: u32) -> Vec<f32> {
        self.gate.wait();
        self.inner.init(num_vertices)
    }

    fn apply(&self, old: f32, reduced: f32) -> f32 {
        self.inner.apply(old, reduced)
    }
}

struct Harness {
    svc: Service,
    /// Executions of the `count` program (factory instantiations).
    runs: Arc<AtomicU64>,
    /// Two-party barrier shared with the `gate` program.
    gate: Arc<Barrier>,
    /// Execution order of the `lo`/`hi` programs.
    order: Arc<Mutex<Vec<&'static str>>>,
}

fn harness(workers: usize, queue_depth: usize) -> Harness {
    harness_opts(workers, queue_depth, 1, 1)
}

/// Batching harness: `max_batch > 1` lets a worker claim compatible
/// queued jobs at dequeue. It rides the `REPRO_SHARDS` matrix so batch
/// *formation* is also exercised against the sharded session, where
/// execution falls back to per-job solo runs — formation, accounting
/// and bit-identity must be unchanged either way.
fn harness_batch(workers: usize, queue_depth: usize, max_batch: usize) -> Harness {
    harness_opts(workers, queue_depth, max_batch, default_shards())
}

fn harness_opts(workers: usize, queue_depth: usize, max_batch: usize, shards: u32) -> Harness {
    let runs = Arc::new(AtomicU64::new(0));
    let gate = Arc::new(Barrier::new(2));
    let order = Arc::new(Mutex::new(Vec::new()));

    let mut reg = AlgorithmRegistry::with_builtins();
    reg.register("boom", |_: &AlgoParams| -> anyhow::Result<BoxedProgram> {
        panic!("boom: injected test panic")
    });
    {
        let runs = Arc::clone(&runs);
        reg.register("count", move |p: &AlgoParams| -> anyhow::Result<BoxedProgram> {
            runs.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(Bfs::new(p.source)))
        });
    }
    {
        let gate = Arc::clone(&gate);
        reg.register("gate", move |p: &AlgoParams| -> anyhow::Result<BoxedProgram> {
            Ok(Box::new(GateBfs { inner: Bfs::new(p.source), gate: Arc::clone(&gate) }))
        });
    }
    for name in ["lo", "hi"] {
        let order = Arc::clone(&order);
        reg.register(name, move |p: &AlgoParams| -> anyhow::Result<BoxedProgram> {
            order.lock().unwrap().push(name);
            Ok(Box::new(Bfs::new(p.source)))
        });
    }

    let session = Session::builder()
        .registry(reg)
        .parallelism(default_threads())
        .shards(shards)
        .build()
        .unwrap();
    let svc = Service::with_session_batch(Arc::new(session), workers, queue_depth, max_batch);
    Harness { svc, runs, gate, order }
}

#[test]
fn panicking_job_costs_one_job_not_one_worker() {
    // The original bug: a panicking job killed its worker thread; at
    // workers=1 the service then hung forever. Now the panic is caught,
    // typed, and the same single worker keeps serving — twice over, so
    // the post-panic executor rebuild is exercised repeatedly.
    let h = harness(1, 0);
    for round in 0..2 {
        let err = h
            .svc
            .submit_blocking(JobSpec::new(Dataset::Tiny, "boom"))
            .unwrap_err();
        match err.downcast_ref::<JobError>() {
            Some(JobError::Panicked(msg)) => {
                assert!(msg.contains("boom"), "round {round}: payload lost: {msg}")
            }
            other => panic!("round {round}: expected Panicked, got {other:?} ({err:#})"),
        }
        let res = h.svc.submit_blocking(JobSpec::new(Dataset::Tiny, "bfs")).unwrap();
        assert_eq!(res.report.algorithm, "bfs", "round {round}");
        assert!(res.report.counts.mvm_ops > 0, "round {round}");
    }
    let snap = h.svc.snapshot();
    assert_eq!(snap.jobs_submitted, 4);
    assert_eq!((snap.jobs_completed, snap.jobs_failed, snap.jobs_shed), (2, 2, 0));
    assert_eq!(snap.per_algorithm["boom"].failed, 2);
    assert!(snap.per_algorithm.values().all(|s| s.queue_depth == 0));
}

#[test]
fn queued_duplicates_share_one_execution_bit_identically() {
    // Four identical specs queued behind the gate must produce ONE
    // factory instantiation (one execution) and four bit-identical
    // results, three of them marked coalesced.
    let h = harness(1, 0);
    let gate_pending = h.svc.submit(JobSpec::new(Dataset::Tiny, "gate")).unwrap();
    let dup = || JobSpec::new(Dataset::Tiny, "count").with_source(1);
    let pending: Vec<_> = (0..4).map(|_| h.svc.submit(dup()).unwrap()).collect();
    h.gate.wait(); // release the worker
    gate_pending.wait().unwrap();
    let results: Vec<_> = pending.into_iter().map(|p| p.wait().unwrap()).collect();

    assert_eq!(h.runs.load(Ordering::SeqCst), 1, "one execution must serve all four");
    assert_eq!(
        results.iter().filter(|r| !r.coalesced).count(),
        1,
        "exactly one leader among the four"
    );
    let first = &results[0].report;
    for (i, r) in results.iter().enumerate() {
        assert_eq!(
            r.report.run.as_ref().unwrap().values,
            first.run.as_ref().unwrap().values,
            "rider {i}: values diverge"
        );
        assert_eq!(r.report.counts, first.counts, "rider {i}: counts diverge");
        assert_eq!(r.report.exec_time_ns, first.exec_time_ns, "rider {i}: time diverges");
        assert_eq!(r.wall_time_us, r.queue_wait_us + r.exec_us, "rider {i}: latency split");
    }

    let snap = h.svc.snapshot();
    assert_eq!(snap.jobs_completed, 5, "gate + all four riders complete");
    assert_eq!(snap.jobs_coalesced, 3);
    assert_eq!(snap.per_algorithm["count"].completed, 4);
    assert_eq!(snap.per_algorithm["count"].coalesced, 3);
    assert!(snap.per_algorithm.values().all(|s| s.queue_depth == 0));
    // gate + count both map to the unweighted Tiny artifact: one Alg.-1
    // run total, so the coalesced jobs added zero preprocessing too.
    assert_eq!(h.svc.session().artifacts().stats().misses, 1);
}

#[test]
fn expired_deadline_jobs_are_shed_without_executing() {
    let h = harness(1, 0);
    let gate_pending = h.svc.submit(JobSpec::new(Dataset::Tiny, "gate")).unwrap();
    // Zero budget: already expired by the time the worker can dequeue it.
    let doomed = h
        .svc
        .submit(
            JobSpec::new(Dataset::Tiny, "count")
                .with_source(2)
                .with_deadline(Duration::ZERO),
        )
        .unwrap();
    h.gate.wait();
    gate_pending.wait().unwrap();

    let err = doomed.wait().unwrap_err();
    match err.downcast_ref::<JobError>() {
        Some(JobError::DeadlineExceeded { .. }) => {}
        other => panic!("expected DeadlineExceeded, got {other:?} ({err:#})"),
    }
    assert_eq!(h.runs.load(Ordering::SeqCst), 0, "shed job must never execute");

    let snap = h.svc.snapshot();
    assert_eq!(snap.jobs_submitted, 2);
    assert_eq!((snap.jobs_completed, snap.jobs_failed, snap.jobs_shed), (1, 0, 1));
    let count = &snap.per_algorithm["count"];
    assert_eq!(count.shed, 1);
    assert_eq!(count.queue_wait.count, 1, "shed jobs still report their queue wait");
    assert_eq!(count.execution.count, 0, "…but no execution sample");
    assert!(snap.per_algorithm.values().all(|s| s.queue_depth == 0));
}

#[test]
fn higher_priority_jobs_dequeue_first() {
    // Submission order lo-then-hi, execution order hi-then-lo: the
    // queue is ordered, not FIFO, once priorities differ.
    let h = harness(1, 0);
    let gate_pending = h.svc.submit(JobSpec::new(Dataset::Tiny, "gate")).unwrap();
    let lo = h.svc.submit(JobSpec::new(Dataset::Tiny, "lo")).unwrap();
    let hi = h.svc.submit(JobSpec::new(Dataset::Tiny, "hi").with_priority(5)).unwrap();
    h.gate.wait();
    gate_pending.wait().unwrap();
    lo.wait().unwrap();
    hi.wait().unwrap();
    assert_eq!(*h.order.lock().unwrap(), ["hi", "lo"]);
}

#[test]
fn coalesced_followers_bypass_the_queue_bound() {
    // queue_depth=1 and the single worker parked: the one slot is taken
    // by the leader, yet three identical followers still submit without
    // blocking — coalesced riders never occupy a slot. (If they did,
    // this test would deadlock, not merely fail.)
    let h = harness(1, 1);
    let gate_pending = h.svc.submit(JobSpec::new(Dataset::Tiny, "gate")).unwrap();
    let dup = || JobSpec::new(Dataset::Tiny, "count").with_source(5);
    let leader = h.svc.submit(dup()).unwrap();
    let followers: Vec<_> = (0..3).map(|_| h.svc.submit(dup()).unwrap()).collect();
    h.gate.wait();
    gate_pending.wait().unwrap();
    leader.wait().unwrap();
    for f in followers {
        f.wait().unwrap();
    }
    assert_eq!(h.runs.load(Ordering::SeqCst), 1);
    let snap = h.svc.snapshot();
    assert_eq!(snap.jobs_coalesced, 3);
    assert_eq!(snap.jobs_completed, 5);
}

#[test]
fn bounded_queue_backpressures_submitters_without_deadlock() {
    // Eight distinct jobs through a depth-1 queue and one worker: every
    // submit after the first blocks until the worker frees the slot.
    // The run completing at all proves the space-condvar handshake;
    // the counters prove nothing was dropped on the way.
    let h = harness(1, 1);
    let specs: Vec<_> =
        (0..8u32).map(|i| JobSpec::new(Dataset::Tiny, "bfs").with_source(i)).collect();
    std::thread::scope(|scope| {
        let svc = &h.svc;
        let submitter =
            scope.spawn(move || specs.into_iter().map(|s| svc.submit(s).unwrap()).collect::<Vec<_>>());
        for p in submitter.join().unwrap() {
            p.wait().unwrap();
        }
    });
    let snap = h.svc.snapshot();
    assert_eq!(snap.jobs_submitted, 8);
    assert_eq!(snap.jobs_completed, 8);
}

#[test]
fn failed_batch_submit_returns_live_handles() {
    // The original bug: a mid-batch submit failure dropped the handles
    // of already-queued jobs — live executions with unobservable
    // results. Now they come back inside the error.
    let h = harness(1, 0);
    let batch = vec![
        JobSpec::new(Dataset::Tiny, "bfs"),
        JobSpec::new(Dataset::Tiny, "bfs").with_scale(2.0), // invalid: scale > 1
        JobSpec::new(Dataset::Tiny, "wcc"),
    ];
    let err = h.svc.submit_batch(batch).err().expect("batch must fail at the invalid spec");
    assert_eq!(err.index, 1);
    assert!(format!("{err}").contains("scale"), "error must surface the cause: {err}");

    let handles = err.take_submitted();
    assert_eq!(handles.len(), 1, "job 0 was already queued and must come back");
    assert!(err.take_submitted().is_empty(), "take_submitted is idempotent");
    let res = handles.into_iter().next().unwrap().wait().unwrap();
    assert_eq!(res.report.algorithm, "bfs");

    // The invalid spec was rejected before any recording; the metrics
    // see exactly one job, completed.
    let snap = h.svc.snapshot();
    assert_eq!((snap.jobs_submitted, snap.jobs_completed, snap.jobs_failed), (1, 1, 0));
}

#[test]
fn metrics_conserve_under_hostile_mixed_bursts() {
    // Property: submitted == completed + failed + shed — globally and
    // per algorithm — across random mixes of healthy jobs, duplicates
    // (coalescing), unknown algorithms (failures), panicking jobs
    // (caught failures) and zero-deadline jobs (sheds), at random
    // worker counts.
    let algos = ["bfs", "wcc", "nope", "count", "boom", "sssp"];
    for seed in 0..5u64 {
        let mut rng = SplitMix64::new(seed);
        let workers = 1 + rng.next_index(4);
        let h = harness(workers, 0);
        let njobs = 6 + rng.next_index(18);
        let pending: Vec<_> = (0..njobs)
            .map(|_| {
                let mut spec = JobSpec::new(Dataset::Tiny, algos[rng.next_index(algos.len())])
                    .with_source(rng.next_index(3) as u32)
                    .with_iterations(3);
                if rng.next_bool(0.25) {
                    // Already expired at submit: guaranteed shed.
                    spec = spec.with_deadline(Duration::ZERO);
                }
                if rng.next_bool(0.3) {
                    spec = spec.with_priority(rng.next_index(5) as i8);
                }
                h.svc.submit(spec).unwrap()
            })
            .collect();
        let mut completed = 0u64;
        for p in pending {
            if p.wait().is_ok() {
                completed += 1;
            }
        }
        let snap = h.svc.snapshot();
        assert_eq!(snap.jobs_submitted, njobs as u64, "seed {seed}");
        assert_eq!(snap.jobs_completed, completed, "seed {seed}");
        assert_eq!(
            snap.jobs_completed + snap.jobs_failed + snap.jobs_shed,
            njobs as u64,
            "seed {seed}: conservation"
        );
        let per: u64 =
            snap.per_algorithm.values().map(|s| s.completed + s.failed + s.shed).sum();
        assert_eq!(per, njobs as u64, "seed {seed}: per-algo conservation");
        assert!(
            snap.per_algorithm.values().all(|s| s.queue_depth == 0),
            "seed {seed}: in-flight gauge must drain: {:?}",
            snap.per_algorithm
        );
        // Histogram conservation: completions and sheds each leave a
        // queue-wait sample; only completions leave an execution sample.
        assert_eq!(
            snap.queue_wait.count,
            snap.jobs_completed + snap.jobs_shed,
            "seed {seed}: queue-wait samples"
        );
        assert_eq!(snap.execution.count, snap.jobs_completed, "seed {seed}: execution samples");
    }
}

#[test]
fn latency_percentiles_are_monotone_and_bounded() {
    let h = harness(2, 0);
    let mix = ["bfs", "wcc", "pagerank", "sssp"];
    let pending: Vec<_> = (0..24)
        .map(|i| {
            h.svc
                .submit(
                    JobSpec::new(Dataset::Tiny, mix[i % mix.len()])
                        .with_source((i / mix.len()) as u32)
                        .with_iterations(3),
                )
                .unwrap()
        })
        .collect();
    for p in pending {
        p.wait().unwrap();
    }
    let snap = h.svc.snapshot();
    assert_eq!(snap.jobs_completed, 24);

    fn check(s: &LatencySummary, what: &str) {
        assert!(s.count > 0, "{what}: no samples");
        assert!(s.p50_us <= s.p99_us, "{what}: p50 {} > p99 {}", s.p50_us, s.p99_us);
        assert!(s.p99_us <= s.p999_us, "{what}: p99 {} > p999 {}", s.p99_us, s.p999_us);
        assert!(s.p999_us <= s.max_us, "{what}: p999 {} > max {}", s.p999_us, s.max_us);
        assert!(s.mean_us <= s.max_us as f64, "{what}: mean {} > max {}", s.mean_us, s.max_us);
    }
    check(&snap.queue_wait, "global queue-wait");
    check(&snap.execution, "global execution");
    for (algo, st) in &snap.per_algorithm {
        check(&st.queue_wait, &format!("{algo} queue-wait"));
        check(&st.execution, &format!("{algo} execution"));
        assert_eq!(st.execution.count, st.completed, "{algo}: one execution sample per completion");
    }
}

#[test]
fn ops_are_recorded_once_per_execution_even_when_the_leader_is_shed() {
    // Regression: completion ops used to be taken only from the rider
    // with `coalesced: false`. If that leader rider expired at dequeue
    // while its coalesced followers survived, the execution ran, the
    // followers completed — and the execution's ops never reached
    // `subgraph_ops`. Ops now land exactly once per execution with the
    // first delivered rider, whatever its role.
    let h = harness(1, 0);
    let gate_pending = h.svc.submit(JobSpec::new(Dataset::Tiny, "gate")).unwrap();
    let dup = || JobSpec::new(Dataset::Tiny, "count").with_source(3);
    // Leader already expired at submit; followers coalesce onto it with
    // no deadline and must survive the dequeue-time shed.
    let leader = h.svc.submit(dup().with_deadline(Duration::ZERO)).unwrap();
    let followers: Vec<_> = (0..2).map(|_| h.svc.submit(dup()).unwrap()).collect();
    h.gate.wait();
    let gate_res = gate_pending.wait().unwrap();

    let err = leader.wait().unwrap_err();
    assert!(
        matches!(err.downcast_ref::<JobError>(), Some(JobError::DeadlineExceeded { .. })),
        "leader must be shed: {err:#}"
    );
    let survivors: Vec<_> = followers.into_iter().map(|f| f.wait().unwrap()).collect();
    assert_eq!(h.runs.load(Ordering::SeqCst), 1, "one execution serves both survivors");
    assert!(survivors.iter().all(|r| r.coalesced), "both survivors are coalesced riders");

    let ops = survivors[0].report.counts.mvm_ops;
    assert!(ops > 0, "the instrument must do real work");
    let snap = h.svc.snapshot();
    assert_eq!((snap.jobs_completed, snap.jobs_shed), (3, 1));
    assert_eq!(
        snap.subgraph_ops,
        gate_res.report.counts.mvm_ops + ops,
        "the shed-leader execution's ops must land exactly once, not zero or twice"
    );
}

#[test]
fn batched_jobs_return_bit_identical_results_to_solo_runs() {
    // Dequeue-time batch formation across batch bounds 1 (off), 2 and
    // 4: four compatible jobs queue behind the gate, the single worker
    // claims them in batches of `max_batch`, and every result must be
    // bit-identical to a solo run of the same spec through the same
    // service. The threads and shards dimensions of the matrix come in
    // via REPRO_THREADS / REPRO_SHARDS (tests/common).
    for max_batch in [1usize, 2, 4] {
        let h = harness_batch(1, 0, max_batch);
        let gate_pending = h.svc.submit(JobSpec::new(Dataset::Tiny, "gate")).unwrap();
        let specs: Vec<_> = (0..4u32)
            .map(|i| JobSpec::new(Dataset::Tiny, "bfs").with_source(i).with_iterations(3))
            .collect();
        let pending: Vec<_> = specs.iter().map(|s| h.svc.submit(s.clone()).unwrap()).collect();
        h.gate.wait();
        gate_pending.wait().unwrap();
        let batched: Vec<_> = pending.into_iter().map(|p| p.wait().unwrap()).collect();

        let snap = h.svc.snapshot();
        if max_batch == 1 {
            assert_eq!(snap.jobs_batched, 0, "max_batch=1 must never form batches");
            assert_eq!(snap.batch_size.count, 0);
        } else {
            assert_eq!(snap.jobs_batched, 4, "max_batch={max_batch}: all four jobs ride batches");
            assert_eq!(
                snap.batch_size.count,
                4 / max_batch as u64,
                "max_batch={max_batch}: batch count"
            );
            assert_eq!(
                snap.batch_size.max_us, max_batch as u64,
                "max_batch={max_batch}: histogram holds batch sizes (jobs), not latencies"
            );
        }
        assert_eq!(snap.jobs_coalesced, 0, "distinct sources must never coalesce");

        // Solo reference runs: the queue is drained, so each blocking
        // submit executes alone through the very same service/session.
        for (spec, batched) in specs.into_iter().zip(&batched) {
            let solo = h.svc.submit_blocking(spec).unwrap();
            let (b, s) = (&batched.report, &solo.report);
            assert_eq!(
                b.run.as_ref().unwrap().values,
                s.run.as_ref().unwrap().values,
                "max_batch={max_batch}: values diverge from solo"
            );
            assert_eq!(b.counts, s.counts, "max_batch={max_batch}: counts diverge");
            assert_eq!(b.exec_time_ns, s.exec_time_ns, "max_batch={max_batch}: model time diverges");
            assert_eq!(b.supersteps, s.supersteps, "max_batch={max_batch}: supersteps diverge");
        }

        let snap = h.svc.snapshot();
        assert_eq!(
            snap.jobs_completed + snap.jobs_failed + snap.jobs_shed,
            snap.jobs_submitted,
            "max_batch={max_batch}: conservation"
        );
    }
}

#[test]
fn metrics_conserve_under_batched_bursts() {
    // The hostile-burst conservation property again, now with a
    // batching worker in the mix: random blends of batch-compatible
    // jobs (one algorithm, few sources), incompatible jobs, panicking
    // factories (exercising the batch → solo fallback) and zero-
    // deadline jobs (shed out of claimed batches) must keep
    // `submitted == completed + failed + shed` and the histogram
    // sample counts exact.
    let algos = ["bfs", "bfs", "bfs", "wcc", "boom"];
    for seed in 0..4u64 {
        let mut rng = SplitMix64::new(seed);
        let workers = 1 + rng.next_index(2);
        let h = harness_batch(workers, 0, 4);
        let njobs = 8 + rng.next_index(16);
        let pending: Vec<_> = (0..njobs)
            .map(|_| {
                let mut spec = JobSpec::new(Dataset::Tiny, algos[rng.next_index(algos.len())])
                    .with_source(rng.next_index(4) as u32)
                    .with_iterations(3);
                if rng.next_bool(0.2) {
                    spec = spec.with_deadline(Duration::ZERO);
                }
                h.svc.submit(spec).unwrap()
            })
            .collect();
        let mut completed = 0u64;
        for p in pending {
            if p.wait().is_ok() {
                completed += 1;
            }
        }
        let snap = h.svc.snapshot();
        assert_eq!(snap.jobs_submitted, njobs as u64, "seed {seed}");
        assert_eq!(snap.jobs_completed, completed, "seed {seed}");
        assert_eq!(
            snap.jobs_completed + snap.jobs_failed + snap.jobs_shed,
            njobs as u64,
            "seed {seed}: conservation"
        );
        assert!(
            snap.jobs_batched <= snap.jobs_completed + snap.jobs_failed,
            "seed {seed}: batched jobs are a subset of delivered jobs"
        );
        assert_eq!(
            snap.queue_wait.count,
            snap.jobs_completed + snap.jobs_shed,
            "seed {seed}: queue-wait samples"
        );
        assert_eq!(snap.execution.count, snap.jobs_completed, "seed {seed}: execution samples");
        assert!(
            snap.per_algorithm.values().all(|s| s.queue_depth == 0),
            "seed {seed}: in-flight gauge must drain"
        );
    }
}
