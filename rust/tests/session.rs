//! Integration tests for the `Session` facade: builder validation, the
//! algorithm registry against the CPU reference oracles, artifact-cache
//! sharing, and backend selection.

use std::path::PathBuf;
use std::sync::Arc;

use repro::accel::ArchConfig;
use repro::algo::reference;
use repro::algo::Bfs;
use repro::graph::datasets::Dataset;
use repro::graph::Csr;
use repro::session::{
    AlgorithmRegistry, ArtifactKey, ArtifactStore, Backend, JobSpec, Session,
};

mod common;
use common::assert_close;

#[test]
fn builder_rejects_invalid_configurations() {
    // Bad architecture.
    let bad_arch = ArchConfig { static_engines: 99, ..ArchConfig::default() };
    let err = Session::builder().arch(bad_arch).build().map(|_| ()).unwrap_err();
    assert!(format!("{err:#}").contains("architecture"), "{err:#}");

    // Empty registry.
    assert!(Session::builder().registry(AlgorithmRegistry::empty()).build().is_err());

    // PJRT without artifacts: loud, names the backend, no fallback.
    let err = Session::builder()
        .backend(Backend::Pjrt(PathBuf::from("/no/such/dir")))
        .build()
        .map(|_| ())
        .unwrap_err();
    assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
}

#[test]
fn run_rejects_bad_specs_loudly() {
    let session = Session::with_defaults().unwrap();
    // Unknown algorithm names every registered id.
    let err = session
        .run(&JobSpec::new(Dataset::Tiny, "dijkstra"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("dijkstra") && err.contains("bfs"), "{err}");
    // Out-of-range scale.
    assert!(session
        .run(&JobSpec::new(Dataset::Tiny, "bfs").with_scale(2.0))
        .is_err());
    // Bad algorithm params (damping ≥ 1 is a factory error, not a panic).
    assert!(session
        .run(&JobSpec::new(Dataset::Tiny, "pagerank").with_damping(1.5))
        .is_err());
}

#[test]
fn registry_runs_all_four_algorithms_to_reference_fixpoints() {
    let session = Session::with_defaults().unwrap();
    let d = Dataset::Tiny;
    let csr = Csr::from_coo(&d.load().unwrap());
    let wcsr = Csr::from_coo(&d.load_weighted(1.0).unwrap());

    let run = |spec: &JobSpec| -> Vec<f32> {
        session.run(spec).unwrap().run.unwrap().values
    };

    assert_close(
        &run(&JobSpec::new(d, "bfs").with_source(2)),
        &reference::bfs_levels(&csr, 2),
        1e-3,
        "bfs",
    );
    assert_close(
        &run(&JobSpec::new(d, "sssp").with_source(2)),
        &reference::sssp_distances(&wcsr, 2),
        1e-2,
        "sssp",
    );
    assert_close(
        &run(&JobSpec::new(d, "pagerank").with_iterations(8)),
        &reference::pagerank(&csr, 0.85, 8),
        1e-4,
        "pagerank",
    );
    assert_close(
        &run(&JobSpec::new(d, "wcc")),
        &reference::wcc_labels(&csr),
        0.0,
        "wcc",
    );
}

#[test]
fn custom_algorithm_is_one_registration() {
    // "Adding an algorithm is one registration, not four match-arm
    // edits": a pinned-source BFS variant becomes runnable everywhere.
    let mut registry = AlgorithmRegistry::with_builtins();
    registry.register("bfs-pinned", |_| Ok(Box::new(Bfs::new(5))));
    let session = Session::builder().registry(registry).build().unwrap();
    let report = session.run(&JobSpec::new(Dataset::Tiny, "bfs-pinned")).unwrap();
    let csr = Csr::from_coo(&Dataset::Tiny.load().unwrap());
    assert_close(
        &report.run.unwrap().values,
        &reference::bfs_levels(&csr, 5),
        1e-3,
        "bfs-pinned",
    );
}

#[test]
fn artifact_store_shared_across_sessions() {
    // Two sessions with the same arch share one store: the second
    // session's first run is a cache hit.
    let store = Arc::new(ArtifactStore::new());
    let spec = JobSpec::new(Dataset::Tiny, "wcc");
    let a = Session::builder().artifacts(Arc::clone(&store)).build().unwrap();
    a.run(&spec).unwrap();
    let b = Session::builder().artifacts(Arc::clone(&store)).build().unwrap();
    b.run(&spec).unwrap();
    let s = store.stats();
    assert_eq!((s.misses, s.hits), (1, 1));

    // A session with a different architecture must NOT be served the
    // cached artifact — the key carries the arch parameters.
    let c = Session::builder()
        .arch(ArchConfig { crossbar_size: 8, ..ArchConfig::default() })
        .artifacts(Arc::clone(&store))
        .build()
        .unwrap();
    c.run(&spec).unwrap();
    let s = store.stats();
    assert_eq!((s.misses, s.hits), (2, 1));
}

#[test]
fn artifact_store_exactly_once_under_thread_hammering() {
    // PR 1 claimed exactly-once preprocessing per key but only asserted
    // it single-threaded through the Session. Hammer one cold key from N
    // threads released together: exactly one Alg.-1 run may happen, every
    // caller must receive the same Arc'd artifact, and the stats must
    // conserve (hits + misses == N, coalesced callers are a subset of
    // the non-builders).
    use repro::accel::Accelerator;
    use std::sync::Barrier;

    const N: usize = 16;
    let store = Arc::new(ArtifactStore::new());
    let key = ArtifactKey::new(Dataset::Tiny, 1.0, false, &ArchConfig::default());
    let barrier = Barrier::new(N);
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|_| {
                let store = &store;
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    store
                        .get_or_preprocess(key, &Accelerator::with_defaults())
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (i, r) in results.iter().enumerate() {
        assert!(
            Arc::ptr_eq(&results[0], r),
            "thread {i} got a different artifact instance"
        );
    }
    let s = store.stats();
    assert_eq!(s.misses, 1, "preprocessing must run exactly once, ran {}", s.misses);
    assert_eq!(s.hits as usize, N - 1);
    assert_eq!(s.entries, 1);
    assert_eq!(s.hits + s.misses, N as u64, "every request must be accounted");
    assert!(
        s.coalesced <= s.hits + s.misses - 1,
        "at most N-1 requests can wait behind the builder, got {}",
        s.coalesced
    );
}

#[test]
fn dse_through_session_matches_direct_call() {
    let session = Session::with_defaults().unwrap();
    let spec = JobSpec::new(Dataset::Tiny, "bfs");
    let (best, points) = session.dse(&spec, Some(&[4, 16])).unwrap();
    assert_eq!(points.len(), 2);
    assert!(best == 4 || best == 16);

    let g = Dataset::Tiny.load().unwrap();
    let (best_direct, direct) = repro::dse::find_best_static_split(
        &g,
        session.arch(),
        session.cost_params(),
        &Bfs::new(0),
        Some(&[4, 16]),
    )
    .unwrap();
    assert_eq!(best, best_direct);
    for (a, b) in points.iter().zip(&direct) {
        assert_eq!(a.x, b.x);
        assert_eq!(a.exec_time_ns, b.exec_time_ns);
    }
}

#[test]
fn native_backend_reports_its_name() {
    let session = Session::with_defaults().unwrap();
    assert_eq!(session.backend().name(), "native");
    assert_eq!(session.registry().len(), 4);
}
