//! Differential sharded-execution suite — the lockdown for the
//! cross-shard frontier-exchange scheduler (`sched::exchange`) and the
//! shard-stamped artifact tier.
//!
//! For random graphs × all four algorithms × randomized architectures,
//! the full [`RunResult`] must be **bit-identical** across
//! `shards ∈ {1, 2, 4}` × `threads ∈ {1, 4}` × execution mechanism
//! (sequential delegate, scoped spawn, persistent pools) *and* match the
//! unsharded on-line oracle `sched::oracle::run_reference`. Shards are a
//! data decomposition, never a result dimension — one ULP of divergence
//! is a scheduler bug, not a tolerance question.
//!
//! The persistence half extends the artifact-IO contract: every shard's
//! `.rpa` file round-trips whole-struct-equal under its shard-stamped
//! key, and a warm restart serves a sharded session with zero plan
//! compilations.

use std::sync::Arc;

use repro::accel::{Accelerator, Preprocessed};
use repro::algo::traits::VertexProgram;
use repro::algo::{Bfs, PageRank, Sssp, Wcc};
use repro::cost::CostParams;
use repro::graph::datasets::Dataset;
use repro::graph::generator::{rmat_stream, RmatParams};
use repro::graph::shard::{split, unshard, Sharder};
use repro::graph::Coo;
use repro::sched::executor::NativeExecutor;
use repro::sched::WorkerPool;
use repro::session::{ArtifactKey, DiskStore, JobSpec, Session};
use repro::util::SplitMix64;

mod common;
use common::{
    assert_bit_identical, default_shards, default_threads, random_arch, random_graph,
    with_random_weights,
};

fn shard_refs(pres: &[Preprocessed]) -> Vec<&Preprocessed> {
    pres.iter().collect()
}

#[test]
fn prop_sharded_runs_bit_identical_across_shards_threads_and_oracle() {
    // The PR-9 acceptance property: shard count × thread count is a pure
    // scheduling choice — every combination reproduces the unsharded
    // oracle bit for bit.
    for seed in 900..906u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0x5AAD);
        let source = rng.next_bounded(g.num_vertices as u64) as u32;
        let cfg = random_arch(&mut rng);
        let gw = with_random_weights(&g, &mut rng);
        let bfs = Bfs::new(source);
        let sssp = Sssp::new(source);
        let pagerank = PageRank::new(0.85, 4);
        let wcc = Wcc;
        let programs: [(&dyn VertexProgram, bool); 4] =
            [(&bfs, false), (&sssp, true), (&pagerank, false), (&wcc, false)];
        let acc = Accelerator::new(cfg.clone(), CostParams::default());
        for (program, weighted) in programs {
            let graph = if weighted { &gw } else { &g };
            let pre = acc.preprocess(graph, weighted).unwrap();
            let oracle = repro::sched::oracle::run_reference(
                &cfg,
                &CostParams::default(),
                &pre,
                program,
                &mut NativeExecutor,
            )
            .unwrap();
            for shards in [1usize, 2, 4] {
                let pres = acc.preprocess_sharded(graph, weighted, shards, None).unwrap();
                let refs = shard_refs(&pres);
                for threads in [1usize, 4] {
                    let run = acc
                        .run_sharded(&refs, program, &mut NativeExecutor, threads)
                        .unwrap()
                        .run
                        .unwrap();
                    assert_bit_identical(
                        &run,
                        &oracle,
                        &format!(
                            "seed {seed} algo {} shards={shards} threads={threads} vs oracle",
                            program.name()
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn prop_sharded_pools_bit_identical_and_reusable() {
    // The pooled mechanism (one persistent pool per shard) agrees with
    // the transient path, and reusing the same pools across consecutive
    // runs changes nothing — the serve-loop steady state.
    for seed in 910..914u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0x9001);
        let source = rng.next_bounded(g.num_vertices as u64) as u32;
        let cfg = random_arch(&mut rng);
        let acc = Accelerator::new(cfg.clone(), CostParams::default());
        let program = Bfs::new(source);
        let base = acc
            .run_threaded(&acc.preprocess(&g, false).unwrap(), &program, &mut NativeExecutor, 1)
            .unwrap()
            .run
            .unwrap();
        for shards in [2usize, 4] {
            let pres = acc.preprocess_sharded(&g, false, shards, None).unwrap();
            let refs = shard_refs(&pres);
            let mut pools: Vec<WorkerPool> =
                (0..shards).map(|_| WorkerPool::new(4)).collect();
            for round in 0..2 {
                let run = acc
                    .run_sharded_pooled(&refs, &program, &mut NativeExecutor, &mut pools, 4)
                    .unwrap()
                    .run
                    .unwrap();
                assert_bit_identical(
                    &run,
                    &base,
                    &format!("seed {seed} shards={shards} round={round} [pooled vs seq]"),
                );
            }
        }
    }
}

#[test]
fn prop_shard_rpa_files_roundtrip_and_serve_identically() {
    // Persistence parity per shard: each shard's artifact round-trips
    // whole-struct-equal under its shard-stamped key, the file's embedded
    // key carries the stamp, and the loaded set replays bit-identically.
    for seed in 920..924u64 {
        let g = random_graph(seed);
        let mut rng = SplitMix64::new(seed ^ 0xD15C);
        let arch = random_arch(&mut rng);
        let source = rng.next_bounded(g.num_vertices as u64) as u32;
        let acc = Accelerator::new(arch.clone(), CostParams::default());
        let shards = 3usize;
        let pres = acc.preprocess_sharded(&g, false, shards, None).unwrap();
        let dir = common::scratch_dir("shard-rpa");
        let store = DiskStore::open(&dir).unwrap();
        let base = ArtifactKey::new(Dataset::Tiny, 1.0, false, &arch);
        let mut loaded = Vec::with_capacity(shards);
        for (s, pre) in pres.iter().enumerate() {
            let key = base.with_shard(s as u32, shards as u32);
            assert!(store.save(&key, pre).unwrap(), "seed {seed}: shard {s} first save writes");
            let got = store.load(&key, &arch).unwrap();
            assert_eq!(pre, &got, "seed {seed}: shard {s} round trip");
            loaded.push(got);
        }
        // Every persisted file self-describes its shard stamp.
        let mut stamps: Vec<(u32, u32)> = store
            .entries()
            .iter()
            .map(|p| {
                let k = DiskStore::embedded_key(p).unwrap();
                (k.shard_id(), k.shard_count())
            })
            .collect();
        stamps.sort_unstable();
        assert_eq!(stamps, vec![(0, 3), (1, 3), (2, 3)], "seed {seed}: embedded stamps");
        let program = Bfs::new(source);
        let a = acc
            .run_sharded(&shard_refs(&pres), &program, &mut NativeExecutor, 2)
            .unwrap()
            .run
            .unwrap();
        let b = acc
            .run_sharded(&shard_refs(&loaded), &program, &mut NativeExecutor, 2)
            .unwrap()
            .run
            .unwrap();
        assert_bit_identical(&a, &b, &format!("seed {seed}: loaded shard set vs in-memory"));
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn warm_restart_serves_sharded_session_with_zero_compiles() {
    // A second process pointed at the same artifact directory must serve
    // a sharded session purely from disk — the `artifacts warm --shards`
    // contract — and reproduce the cold run bit for bit.
    let dir = common::scratch_dir("shard-warm");
    let spec = JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(5);
    let cold = Session::builder()
        .shards(2)
        .parallelism(2)
        .artifact_dir(dir.clone())
        .build()
        .unwrap();
    let a = cold.run(&spec).unwrap();
    assert_eq!(cold.artifacts().stats().misses, 2, "cold: one compile per shard");
    drop(cold);
    let warm = Session::builder()
        .shards(2)
        .parallelism(2)
        .artifact_dir(dir.clone())
        .build()
        .unwrap();
    let b = warm.run(&spec).unwrap();
    let s = warm.artifacts().stats();
    assert_eq!(s.misses, 0, "warm restart must not compile any shard");
    assert_eq!(s.disk_hits, 2, "both shard artifacts load from disk");
    assert_bit_identical(
        &a.run.unwrap(),
        &b.run.unwrap(),
        "warm sharded restart vs cold run",
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn session_jobs_honor_the_harness_shard_default() {
    // The REPRO_SHARDS-driven default (CI adds a 2-shard leg) must serve
    // results bit-identical to an explicitly unsharded session through
    // the full Session/ArtifactStore path. `.max(2)` keeps the comparison
    // sharded-vs-unsharded even in the default leg.
    let shards = default_shards().max(2);
    let plain = Session::builder().parallelism(1).build().unwrap();
    let sharded = Session::builder()
        .shards(shards)
        .parallelism(default_threads())
        .build()
        .unwrap();
    for spec in [
        JobSpec::new(Dataset::Tiny, "bfs").with_source(3),
        JobSpec::new(Dataset::Tiny, "sssp").with_source(1),
        JobSpec::new(Dataset::Tiny, "pagerank").with_iterations(6),
        JobSpec::new(Dataset::Tiny, "wcc"),
    ] {
        let a = plain.run(&spec).unwrap();
        let b = sharded.run(&spec).unwrap();
        assert_bit_identical(
            &a.run.unwrap(),
            &b.run.unwrap(),
            &format!("{} at {shards} shards", spec.algorithm.as_str()),
        );
    }
}

#[test]
fn streamed_rmat_shards_match_the_materialized_split() {
    // `rmat_stream` → `Sharder` must equal materialize-then-`split`, and
    // the batch size may only change where the stream is cut — never any
    // shard's content. The streamed shard set then runs end to end,
    // bit-identical to its own unsharded oracle.
    let (n, m, seed) = (512u32, 4096usize, 0xF00Du64);
    let c = 4usize;
    let shards = 4usize;
    for batch in [1usize, 7, 64, 4096] {
        let mut sharder = Sharder::new(n, c, shards);
        let mut all: Vec<repro::graph::coo::Edge> = Vec::new();
        rmat_stream(n, m, RmatParams::default(), seed, batch, |edges| {
            sharder.push(edges);
            all.extend_from_slice(edges);
        });
        let streamed = sharder.finish();
        let want = split(&Coo::from_edges(n, all), c, shards);
        assert_eq!(streamed.len(), want.len(), "batch {batch}: shard count");
        for (got, want) in streamed.iter().zip(&want) {
            assert_eq!(got.shard_id, want.shard_id, "batch {batch}: shard id");
            assert_eq!(
                (got.brow_start, got.brow_end),
                (want.brow_start, want.brow_end),
                "batch {batch}: shard {} brow range",
                got.shard_id
            );
            assert_eq!(
                got.graph.num_vertices, want.graph.num_vertices,
                "batch {batch}: shard {} vertex space",
                got.shard_id
            );
            assert_eq!(
                got.graph.edges, want.graph.edges,
                "batch {batch}: shard {} edges diverge from materialized split",
                got.shard_id
            );
        }
        if batch == 64 {
            // The streaming compile (never materializing the global edge
            // list) must equal the materialized compile of its unshard,
            // and its run must reproduce the unsharded oracle.
            let g = unshard(&streamed);
            let cfg = repro::accel::ArchConfig { crossbar_size: c, ..Default::default() };
            let acc = Accelerator::new(cfg.clone(), CostParams::default());
            let pre = acc.preprocess(&g, false).unwrap();
            let oracle = repro::sched::oracle::run_reference(
                &cfg,
                &CostParams::default(),
                &pre,
                &Wcc,
                &mut NativeExecutor,
            )
            .unwrap();
            let from_stream: Vec<Preprocessed> = acc
                .preprocess_shard_graphs_timed(&streamed, false, None)
                .unwrap()
                .into_iter()
                .map(|(p, _)| p)
                .collect();
            let from_coo = acc.preprocess_sharded(&g, false, shards, None).unwrap();
            assert_eq!(
                from_stream, from_coo,
                "streamed shard compile diverges from the materialized one"
            );
            let run = acc
                .run_sharded(&shard_refs(&from_stream), &Wcc, &mut NativeExecutor, 2)
                .unwrap()
                .run
                .unwrap();
            assert_bit_identical(&run, &oracle, "streamed sharded wcc vs oracle");
        }
    }
}

#[test]
#[ignore = "100M-edge stream; minutes of CPU and several GB of RAM — run explicitly with --ignored"]
fn huge_streamed_rmat_runs_end_to_end_sharded_without_materializing() {
    // The scale target behind `rmat_stream` + `Sharder`: a 100M-edge
    // R-MAT graph (beyond every SNAP preset) streams in bounded batches
    // straight into per-shard buckets — the global edge list never
    // exists in one `Vec` — then compiles through the streaming shard
    // entry and runs WCC end to end through the exchange scheduler.
    let (n, m, seed) = (1u32 << 24, 100_000_000usize, 42u64);
    let shards = 4usize;
    let c = 4usize;
    let mut sharder = Sharder::new(n, c, shards);
    let emitted = rmat_stream(n, m, RmatParams::default(), seed, 1 << 20, |edges| {
        sharder.push(edges);
    });
    assert!(emitted >= m / 2, "retry budget should cover most of the request");
    let shard_graphs = sharder.finish();
    assert_eq!(shard_graphs.len(), shards);
    let total: usize = shard_graphs.iter().map(|s| s.num_edges()).sum();
    assert!(total > 10_000_000, "dedup should still leave a huge graph, got {total}");
    let cfg = repro::accel::ArchConfig { crossbar_size: c, ..Default::default() };
    let acc = Accelerator::new(cfg, CostParams::default());
    let pres: Vec<Preprocessed> = acc
        .preprocess_shard_graphs_timed(&shard_graphs, false, None)
        .unwrap()
        .into_iter()
        .map(|(p, _)| p)
        .collect();
    drop(shard_graphs);
    let report = acc
        .run_sharded(&shard_refs(&pres), &Wcc, &mut NativeExecutor, 4)
        .unwrap();
    let run = report.run.unwrap();
    assert_eq!(run.values.len(), n as usize, "one label per vertex");
    assert!(run.supersteps > 0 && run.counts.mvm_ops > 0, "the sharded run did real work");
}

#[test]
fn sharded_session_runs_are_arc_shared_not_recompiled() {
    // Repeat jobs on a sharded session hit the memory tier: the second
    // run adds no misses and the artifacts are the same Arc allocations.
    let session = Session::builder().shards(3).build().unwrap();
    let spec = JobSpec::new(Dataset::Tiny, "wcc");
    let first = session.preprocess_sharded(&spec).unwrap();
    let misses = session.artifacts().stats().misses;
    assert_eq!(misses, 3, "one compile per shard");
    let second = session.preprocess_sharded(&spec).unwrap();
    assert_eq!(session.artifacts().stats().misses, misses, "no recompiles");
    for (a, b) in first.iter().zip(&second) {
        assert!(Arc::ptr_eq(a, b), "memory tier must share the same artifact");
    }
}
